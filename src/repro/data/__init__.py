from repro.data.pipeline import DataState, make_pipeline  # noqa: F401
