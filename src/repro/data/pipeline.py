"""Deterministic, restartable, shardable synthetic data pipeline.

Design constraints for 1000+-node training:
  * the pipeline state is a tiny pure value (step counter + PRNG key), so a
    restart from checkpoint resumes the exact token stream — no data-loader
    state to rescue from a dead host;
  * every host can materialize exactly its shard of the global batch from
    (step, host_id) alone — no central dispatcher, no skew: this is the
    deterministic data assignment that makes straggler *re-assignment*
    trivial (any survivor can recompute a dead host's shard);
  * mixture weights are static config, so eval/ablation streams are
    reproducible.

Synthetic corpus: a mixture of Zipfian unigram draws and shifted-window
"copy runs" (so models have learnable structure) — enough to drive real
training-loop dynamics without external data dependencies.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class DataState(NamedTuple):
    step: jnp.ndarray  # int32
    seed: int


def _zipf_tokens(key, shape, vocab: int):
    """Zipf-ish draw via exponentiated uniforms (cheap, vectorized)."""
    u = jax.random.uniform(key, shape, jnp.float32, 1e-6, 1.0)
    r = jnp.exp(u * jnp.log(float(vocab))) - 1.0
    return jnp.clip(r.astype(jnp.int32), 0, vocab - 1)


def make_pipeline(vocab: int, batch: int, seq: int, *, copy_frac: float = 0.3, seed: int = 0):
    """Returns (init_state, next_batch) with next_batch(state) -> (state', batch)."""

    def init_state() -> DataState:
        return DataState(jnp.zeros((), jnp.int32), seed)

    def next_batch(state: DataState) -> Tuple[DataState, Dict[str, jnp.ndarray]]:
        key = jax.random.fold_in(jax.random.PRNGKey(state.seed), state.step)
        k1, k2, k3 = jax.random.split(key, 3)
        toks = _zipf_tokens(k1, (batch, seq), vocab)
        # copy runs: second half repeats the first half for a subset of rows
        half = seq // 2
        copied = jnp.concatenate([toks[:, :half], toks[:, :half]], axis=1)
        copied = jnp.pad(copied, ((0, 0), (0, seq - 2 * half)))[:, :seq]
        is_copy = jax.random.uniform(k2, (batch, 1)) < copy_frac
        toks = jnp.where(is_copy, copied, toks)
        out = {"tokens": toks, "labels": toks}
        return DataState(state.step + 1, state.seed), out

    return init_state, next_batch


def shard_for_host(batch: Dict[str, jnp.ndarray], host_id: int, n_hosts: int):
    """Deterministic host shard of a global batch (row-sliced)."""
    out = {}
    for k, v in batch.items():
        per = v.shape[0] // n_hosts
        out[k] = v[host_id * per : (host_id + 1) * per]
    return out
