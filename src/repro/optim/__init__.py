from repro.optim.optimizers import (  # noqa: F401
    OptimizerSpec,
    adamw,
    momentum_bf16,
    clip_by_global_norm,
    make_optimizer,
    wsd_schedule,
)
