"""Optimizers (pure JAX, optax-style API): AdamW and memory-lean bf16 momentum.

Optimizer states inherit the parameter sharding specs (ZeRO-style: states are
sharded exactly like the params they track, so adding an optimizer never
changes the communication pattern of the step).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class OptimizerSpec(NamedTuple):
    init: Callable[[Any], Any]  # params -> opt_state
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    # (grads, opt_state, params, step) -> (new_params, new_opt_state)


def wsd_schedule(peak_lr: float, warmup: int = 100, decay_start: int = 10_000, total: int = 20_000):
    """Warmup-stable-decay schedule."""

    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * jnp.minimum((s + 1.0) / max(warmup, 1), 1.0)
        frac = jnp.clip((s - decay_start) / max(total - decay_start, 1), 0.0, 1.0)
        decay = peak_lr * (1.0 - 0.9 * frac)
        return jnp.where(s < decay_start, warm, decay)

    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw(
    lr: Callable,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
) -> OptimizerSpec:
    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        }

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1**t
        bc2 = 1.0 - b2**t
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        lr_t = lr(step)

        def upd(p, mm, vv):
            u = (mm / bc1) / (jnp.sqrt(vv / bc2) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v}, gnorm

    return OptimizerSpec(init, update)


def momentum_bf16(
    lr: Callable,
    beta: float = 0.9,
    weight_decay: float = 0.0,
    max_grad_norm: float = 1.0,
) -> OptimizerSpec:
    """Memory-lean SGD-momentum with bf16 state — for trillion-param configs
    where AdamW's 8 fp32 bytes/param cannot fit the per-device HBM budget."""

    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.bfloat16), params)}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        m = jax.tree.map(
            lambda mm, g: (beta * mm.astype(jnp.float32) + g.astype(jnp.float32)).astype(jnp.bfloat16),
            state["m"],
            grads,
        )
        lr_t = lr(step)

        def upd(p, mm):
            u = mm.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)

        return jax.tree.map(upd, params, m), {"m": m}, gnorm

    return OptimizerSpec(init, update)


def make_optimizer(name: str, peak_lr: float = 3e-4, **kw) -> OptimizerSpec:
    sched = wsd_schedule(peak_lr)
    if name == "adamw":
        return adamw(sched, **kw)
    if name == "momentum_bf16":
        return momentum_bf16(sched, **kw)
    raise ValueError(name)


def opt_state_specs(opt_name: str, param_specs):
    """Optimizer-state logical specs mirror the param specs."""
    if opt_name == "adamw":
        return {"m": param_specs, "v": param_specs}
    return {"m": param_specs}
