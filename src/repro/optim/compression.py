"""Gradient compression for cross-pod reduction (distributed-optimization).

At 2+ pods the gradient all-reduce crosses the slow inter-pod links; int8
quantization with error feedback cuts that traffic 4x (bf16->int8) at no
asymptotic accuracy cost (the residual is fed back into the next step —
1-bit/âdam-style EF-SGD argument).

Two pieces:
  * `compressed_psum(x, axis)` — shard_map-compatible quantized psum for
    the production cross-pod reduction (int8 on the wire, int32 reduce).
  * `with_error_feedback(opt, bits)` — optimizer wrapper that runs the
    quantize/dequantize + residual carry; exact on the local path, so it
    can be validated single-device (tests/test_substrate.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.optim.optimizers import OptimizerSpec


def quantize_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Quantized psum: int8 on the wire, exact int32 reduction, rescale.

    Call inside shard_map over the cross-pod axis.  Scales are psum-maxed
    first so all participants share one grid (one tiny fp32 collective).
    """
    q, scale = quantize_int8(x)
    g_scale = jax.lax.pmax(scale, axis)
    # re-quantize against the global scale so the int32 sum is consistent
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / g_scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis)
    return total.astype(jnp.float32) * g_scale


def with_error_feedback(opt: OptimizerSpec, enabled: bool = True) -> OptimizerSpec:
    """Wrap an optimizer with int8 gradient quantization + error feedback."""
    if not enabled:
        return opt

    def init(params):
        return {
            "inner": opt.init(params),
            "residual": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params, step):
        def comp(g, r):
            gq = g.astype(jnp.float32) + r
            q, scale = quantize_int8(gq)
            deq = dequantize_int8(q, scale)
            return deq.astype(g.dtype), gq - deq

        pairs = jax.tree.map(comp, grads, state["residual"])
        cgrads = jax.tree.map(lambda pr: pr[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        resid = jax.tree.map(lambda pr: pr[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        new_params, inner, gnorm = opt.update(cgrads, state["inner"], params, step)
        return new_params, {"inner": inner, "residual": resid}, gnorm

    return OptimizerSpec(init, update)
