"""Shared workload-factory arithmetic that must work for BOTH concrete and
traced ``n_records``.

Under bucketed static-axis padding (repro.core.sweep, DESIGN.md §6) the
record count becomes a traced per-config knob, so the factories' sizing
arithmetic (hot-set floors, per-warehouse partitions) can no longer assume
a Python int.  These helpers pick the Python path for concrete ints — the
historical code path, so pinned golden counters cannot drift — and the
jnp path for traced values, with matching truncation semantics.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def is_concrete(n) -> bool:
    return isinstance(n, (int, np.integer))


def imin(a, b):
    """min for (possibly traced) integer counts."""
    if is_concrete(a) and is_concrete(b):
        return min(int(a), int(b))
    return jnp.minimum(a, b)


def imax(a, b):
    """max for (possibly traced) integer counts."""
    if is_concrete(a) and is_concrete(b):
        return max(int(a), int(b))
    return jnp.maximum(a, b)


def scaled_count(n, frac: float, floor: int):
    """``max(int(n * frac), floor)`` for concrete or traced n.

    BOTH paths multiply in float32 and truncate toward zero — the traced
    path cannot do better (x64 is off), so the concrete path must match
    it, not the other way round: with float64 on one side the two would
    disagree at paper-scale counts (e.g. n=11_012_999, frac=0.001 is
    11012 in float64 but 11013 in float32), silently breaking the
    padded==unpadded bitwise contract of DESIGN.md §6.
    """
    if is_concrete(n):
        return max(int(np.float32(int(n)) * np.float32(frac)), floor)
    return jnp.maximum((n * frac).astype(jnp.int32), jnp.int32(floor))
