"""YCSB (paper §6.1): 1 table, 64B records (16 words), 10 ops/txn,
80% read / 20% write, 0.1% hot area, configurable hot-access probability
(contention knob) and execution-phase computation time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine import Workload
from repro.workloads.util import scaled_count

RW = 16  # 64-byte records
K = 10


def make_ycsb(
    n_records,
    hot_prob: float = 0.10,
    hot_frac: float = 0.001,
    write_frac: float = 0.20,
    exec_ticks: int = 3,  # ~5us execution phase at tick=2us
) -> Workload:
    # floor the hot set so tiny test stores don't degenerate to a
    # single record (the paper's 0.1% presumes millions of records).
    # n_records may be a traced knob under bucketed record padding.
    n_hot = scaled_count(n_records, hot_frac, 16)

    def gen(key, node, slot):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        hot = jax.random.uniform(k1, (K,)) < hot_prob
        cold = jax.random.randint(k2, (K,), n_hot, n_records)
        hot_keys = jax.random.randint(k3, (K,), 0, n_hot)
        keys = jnp.where(hot, hot_keys, cold).astype(jnp.int32)
        # de-duplicate within the txn (lock re-entrance not modeled): nudge
        # colliding keys; multiple rounds make residual collisions ~(K/n)^4
        def dedup(i, r, ks, slot=slot):
            clash = (ks[:i] == ks[i]).any()
            return ks.at[i].set(jnp.where(clash, (ks[i] + i * 131 + r * 37 + slot * 13 + 1) % n_records, ks[i]))

        for r in range(4):
            for i in range(1, K):
                keys = dedup(i, r, keys)
        is_w = jax.random.uniform(k4, (K,)) < write_frac
        valid = jnp.ones((K,), bool)
        return keys, is_w, valid

    def execute(keys, is_w, valid, rvals):
        return rvals + 1  # field increment

    return Workload(
        name="ycsb", rw=RW, max_ops=K, init_value=0, gen=gen, execute=execute, exec_ticks=exec_ticks
    )
