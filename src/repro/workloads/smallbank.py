"""SmallBank (paper §6.1): banking app; <3 reads/writes per txn, trivial
arithmetic — network-intensive.  Accounts have (checking, savings) balances.

Txn mix (H-Store SmallBank): amalgamate, balance (read-only), deposit-
checking, send-payment, transact-savings, write-check — we model the access
patterns (1-2 accounts, read or read-modify-write) with exact RS/WS shapes;
the arithmetic is executed in `execute`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine import Workload
from repro.workloads.util import imin

RW = 2  # record: (checking, savings)
K = 2  # max ops per txn
HOT_FRAC = 0.25  # fraction of accesses hitting the hot 100 accounts


def make_smallbank(n_records, hot_accounts: int = 100, exec_ticks: int = 1) -> Workload:
    # n_records may be a traced knob under bucketed record padding
    n_hot = imin(hot_accounts, n_records)

    def gen(key, node, slot):
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        ttype = jax.random.randint(k1, (), 0, 6)
        hot = jax.random.uniform(k2, (2,)) < HOT_FRAC
        acct = jax.random.randint(k3, (2,), 0, n_records)
        acct_hot = jax.random.randint(k4, (2,), 0, n_hot)
        a = jnp.where(hot, acct_hot, acct)
        a = jnp.where(a[1] == a[0], (a + jnp.arange(2)) % n_records, a)  # distinct
        keys = a.astype(jnp.int32)
        # balance() is read-only single-account; send_payment touches 2
        two_accounts = (ttype == 0) | (ttype == 3)  # amalgamate / send-payment
        read_only = ttype == 1  # balance
        valid = jnp.stack([jnp.bool_(True), two_accounts])
        is_w = jnp.stack([~read_only, two_accounts & ~read_only])
        return keys, is_w, valid

    def execute(keys, is_w, valid, rvals):
        # transfer: move amount 1 from checking[0] to checking[1]; single-
        # account writes deposit +1 to checking. conserves total balance.
        amt = jnp.int32(1)
        w0 = rvals[0].at[0].add(jnp.where(valid[1], -amt, amt))
        w1 = rvals[1].at[0].add(amt)
        return jnp.stack([w0, w1])

    return Workload(
        name="smallbank",
        rw=RW,
        max_ops=K,
        init_value=1000,
        gen=gen,
        execute=execute,
        exec_ticks=exec_ticks,
    )
