"""TPC-C new-order (paper §6.1): long transactions, up to 15 distributed
writes (stock updates), CPU-intensive execution phase, 100% write ops.

We model the distributed-contention core of new-order: 5-15 stock records
(read-modify-write), ~10% remote-warehouse items, warehouse-local hot rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine import Workload
from repro.workloads.util import imax

RW = 4
K = 15


def make_tpcc_neworder(
    n_records,
    n_warehouses: int = 16,
    remote_prob: float = 0.10,
    exec_ticks: int = 5,
) -> Workload:
    # n_records may be a traced knob under bucketed record padding
    per_wh = imax(n_records // n_warehouses, 1)

    def gen(key, node, slot):
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        n_items = jax.random.randint(k1, (), 5, K + 1)
        wh = (slot * 7 + node) % n_warehouses  # home warehouse
        remote = jax.random.uniform(k2, (K,)) < remote_prob
        wh_i = jnp.where(remote, jax.random.randint(k3, (K,), 0, n_warehouses), wh)
        item = jax.random.randint(k4, (K,), 0, per_wh)
        keys = (wh_i * per_wh + item).astype(jnp.int32)

        def dedup(i, r, ks, slot=slot):
            clash = (ks[:i] == ks[i]).any()
            return ks.at[i].set(jnp.where(clash, (ks[i] + i * 131 + r * 37 + slot * 13 + 1) % n_records, ks[i]))

        for r in range(4):
            for i in range(1, K):
                keys = dedup(i, r, keys)
        valid = jnp.arange(K) < n_items
        is_w = valid  # new-order: all stock accesses are read-modify-write
        return keys, is_w, valid

    def execute(keys, is_w, valid, rvals):
        # stock decrement with wraparound (s_quantity update rule)
        q = rvals[:, 0]
        newq = jnp.where(q > 10, q - 5, q - 5 + 91)
        return rvals.at[:, 0].set(newq).at[:, 1].add(1)  # qty, ytd

    return Workload(
        name="tpcc", rw=RW, max_ops=K, init_value=50, gen=gen, execute=execute, exec_ticks=exec_ticks
    )
