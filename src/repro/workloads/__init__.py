from repro.workloads.smallbank import make_smallbank  # noqa: F401
from repro.workloads.tpcc import make_tpcc_neworder  # noqa: F401
from repro.workloads.ycsb import make_ycsb  # noqa: F401


def make_workload(name: str, n_records: int, **kw):
    if name == "smallbank":
        return make_smallbank(n_records, **kw)
    if name == "ycsb":
        return make_ycsb(n_records, **kw)
    if name == "tpcc":
        return make_tpcc_neworder(n_records, **kw)
    raise ValueError(name)
