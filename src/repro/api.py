"""One front door for RCC experiments: ``plan(spec)`` → ``execute(plan)``.

The repo's engine grew four dispatch layers (dense vmapped grids, a
config-sharded device mesh, node-sharded single configs, and the 2-D
``config × node`` composition) plus a shape-bucketing planner — and every
benchmark hand-rolled the choice between them.  This module owns that
choice declaratively:

    from repro.api import ExperimentSpec, plan, execute

    spec = ExperimentSpec(
        protocol="sundial", workload="smallbank",
        configs=[{"hybrid": c} for c in all_hybrid_codes()],
        ticks=96, coroutines=12, records_per_node=4096, warmup=8,
    )
    pl = plan(spec)        # buckets, mesh layout, compile accounting
    print(pl.summary())    # human-readable: what compiles, on which mesh
    rows = execute(pl).rows

The planner owns what callers used to hand-roll: power-of-two shape
bucketing (``sweep.plan_buckets``), config-axis vs node-axis vs 2-D
``config × node`` mesh selection, remainder padding, per-protocol
capability constraints (e.g. CALVIN grids stay config-axis only —
``Caps.batch_node_shardable=False`` from the protocol registry), and the
expected-compile accounting that scripts/perf_gate.py asserts against.
Protocols come from :mod:`repro.core.registry` — a new protocol is one
module plus one ``register_protocol`` call and every surface above picks
it up by name.

Devices: ``ExperimentSpec.devices`` is ``None`` (single-device dense run,
no placement), ``"auto"`` (all of ``jax.devices()`` — real accelerators or
``--xla_force_host_platform_device_count`` fake hosts), or an explicit
device sequence.  Layout auto-selection can be overridden with
``ExperimentSpec.layout``.

The legacy entry points (``sweep.run_grid`` / ``run_grid_sharded`` /
``run_cell_sharded``) are deprecation shims over this module, so their
counters are bitwise-identical to the ``plan/execute`` path by
construction — and pinned by tests/test_api.py anyway.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import numpy as np

from repro.core import registry
from repro.core import sweep as _sweep
from repro.kernels import ops as _kernel_ops
from repro.core.costmodel import N_HYBRID_STAGES, RPC
from repro.core.sweep import (  # noqa: F401  (public planner helpers, re-exported)
    KNOB_KEYS,
    STATIC_AXES,
    BucketPlan,
    GridSpec,
    all_hybrid_codes,
    grid_product,
    make_knobs,
    normalize_hybrid,
    plan_buckets,
)

AUTO = "auto"

# mesh layouts the planner can select (ExperimentSpec.layout overrides)
DENSE = "dense"  # one device, vmap over the config axis
CONFIG = "config"  # config axis sharded over a 1-D `grid` mesh
NODE = "node"  # ONE config, simulated n_nodes axis SPMD over a `node` mesh
CONFIG_NODE = "config_node"  # 2-D `config × node` mesh (DESIGN.md §7)
LAYOUTS = (DENSE, CONFIG, NODE, CONFIG_NODE)


@dataclass(frozen=True)
class ExperimentSpec:
    """Declarative description of one experiment sweep.

    ``configs`` is a sequence of per-run dicts mixing traced knobs
    (``hybrid``, ``seed``, ``exec_ticks``, ``hot_prob``, ``qp_pressure``)
    with static shape axes (:data:`STATIC_AXES`: ``coroutines``,
    ``records_per_node``, ``ticks``) — the planner buckets the static axes,
    the executor vmaps the knobs.  Everything else is grid-level defaults.
    """

    protocol: str
    workload: str
    configs: Tuple[Dict, ...] = ({},)
    n_nodes: int = 4
    coroutines: int = 60
    records_per_node: int = 65536
    ticks: int = 400
    warmup: int = 80
    history_cap: int = 0
    mvcc_slots: int = 4
    doorbell: bool = True
    tcp: bool = False
    merge_stages: bool = False
    # kernel plane for the fused hot paths (DESIGN.md §9): "auto" resolves
    # per backend at plan time (Pallas on TPU/GPU, jnp on CPU); "jnp",
    # "pallas", "pallas_interpret" pin it.  Counters are bitwise-equal
    # across planes (the kernel-parity CI contract).
    kernel_plane: str = "auto"
    # topology: None = single-device dense; "auto" = all jax.devices();
    # or an explicit device sequence.  node_shards sizes the `node` mesh axis.
    devices: Union[None, str, Tuple[Any, ...]] = None
    node_shards: Optional[int] = None
    layout: Optional[str] = None  # override planner auto-selection

    def __post_init__(self):
        object.__setattr__(self, "configs", tuple(dict(c) for c in self.configs))
        if isinstance(self.devices, (list, tuple)):
            object.__setattr__(self, "devices", tuple(self.devices))


@dataclass(frozen=True)
class PlannedBucket:
    """One shape bucket of the plan: a padded GridSpec (= one XLA program)
    plus the per-config active extents that make the padding inert."""

    index: int
    grid_spec: GridSpec
    bucket: BucketPlan

    def describe(self) -> str:
        b, g = self.bucket, self.grid_spec
        axes = []
        for name, padded, active in (
            ("coroutines", g.coroutines, b.coroutines_active),
            ("records_per_node", g.records_per_node, b.records_active),
            ("ticks", g.ticks, b.ticks_active),
        ):
            if active is None:
                axes.append(f"{name}={padded}")
            else:
                axes.append(f"{name}={padded} (active {min(active)}..{max(active)})")
        return (
            f"bucket {self.index}: {len(b.indices)} config(s), "
            + ", ".join(axes)
            + " -> 1 compile"
        )


@dataclass(frozen=True)
class ExecutionPlan:
    """What :func:`execute` will run: buckets, mesh layout, compile budget."""

    spec: ExperimentSpec
    layout: str
    devices: Optional[Tuple[Any, ...]]  # None = default single device
    node_shards: Optional[int]
    buckets: Tuple[PlannedBucket, ...]
    expected_compiles: int  # cold-cache upper bound; cache hits only lower it
    cache: str = "grid"  # which jit cache the programs land in (compile_stats key)
    kernel_plane: str = "jnp"  # resolved hot-path backend (spec "auto" -> concrete)

    @property
    def n_configs(self) -> int:
        return len(self.spec.configs)

    @property
    def n_devices(self) -> int:
        return len(self.devices) if self.devices is not None else 1

    def mesh_shape(self) -> str:
        if self.layout == DENSE:
            return "1 device (dense vmap)"
        if self.layout == CONFIG:
            return f"1-D grid mesh, {self.n_devices} device(s) on the config axis"
        if self.layout == NODE:
            return f"1-D node mesh, {self.n_devices} device(s) on the n_nodes axis"
        n_cfg = self.n_devices // (self.node_shards or 1)
        return (
            f"2-D config × node mesh, {self.n_devices} device(s) as "
            f"{n_cfg} config-shard(s) × {self.node_shards} node-shard(s)"
        )

    def summary(self) -> str:
        """Human-readable plan: which bucket compiles what, on which mesh."""
        s = self.spec
        lines = [
            f"ExperimentSpec: protocol={s.protocol} workload={s.workload} "
            f"configs={self.n_configs}",
            f"layout: {self.layout} — {self.mesh_shape()}",
        ]
        lines += [pb.describe() for pb in self.buckets]
        lines.append(
            f"kernel plane: {self.kernel_plane} — "
            f"{_kernel_ops.describe_plane(self.kernel_plane)}"
        )
        lines.append(
            f"expected compiles (cold {self.cache!r} cache): {self.expected_compiles}"
        )
        return "\n".join(lines)


@dataclass(frozen=True)
class Results:
    """Executed plan: one metrics dict per config, in ``spec.configs`` order."""

    rows: List[Dict] = field(default_factory=list)
    plan: Optional[ExecutionPlan] = None
    wall_s: float = 0.0

    @property
    def row(self) -> Dict:
        if len(self.rows) != 1:
            raise ValueError(f"Results.row: plan produced {len(self.rows)} rows, not 1")
        return self.rows[0]


def _resolve_devices(spec: ExperimentSpec, *, need: bool) -> Optional[Tuple[Any, ...]]:
    if spec.devices is None:
        return tuple(jax.devices()) if need else None
    if isinstance(spec.devices, str):
        if spec.devices != AUTO:
            raise ValueError(
                f"ExperimentSpec.devices={spec.devices!r}: pass None, 'auto', "
                "or an explicit device sequence"
            )
        return tuple(jax.devices())
    return tuple(spec.devices)


def plan(spec: ExperimentSpec) -> ExecutionPlan:
    """Resolve an :class:`ExperimentSpec` into an executable plan.

    Raises at plan time — before anything compiles — on unknown protocols
    (registry lookup), capability violations (e.g. a 2-D ``config × node``
    mesh for a protocol registered with ``Caps(batch_node_shardable=False)``),
    and topology mismatches (device counts that don't divide).
    """
    entry = registry.get_protocol(spec.protocol)
    if not spec.configs:
        raise ValueError("ExperimentSpec.configs is empty: pass at least one knob dict")
    if spec.layout is not None and spec.layout not in LAYOUTS:
        raise ValueError(f"ExperimentSpec.layout={spec.layout!r}: valid layouts {LAYOUTS}")
    # resolve the kernel plane before anything compiles so the plan reports
    # (and the whole run uses) one concrete backend
    kernel_plane = _kernel_ops.resolve_plane(spec.kernel_plane)

    # node_shards <= 0 means "no node sharding" (CLI flags default to 0)
    node_shards = spec.node_shards if spec.node_shards and spec.node_shards >= 1 else None
    layout = spec.layout
    if layout is None:
        if node_shards is not None and len(spec.configs) == 1:
            layout = NODE
        elif node_shards is not None and node_shards >= 2:
            layout = CONFIG_NODE
        else:
            # node_shards in (None, 1) with a multi-config grid degenerates
            # to no node sharding: pick dense/config from the device count
            node_shards = None
            devices = _resolve_devices(spec, need=False)
            layout = CONFIG if devices is not None and len(devices) > 1 else DENSE

    # capability gates come first: a protocol that cannot run a layout should
    # say so before any device-count arithmetic confuses the message
    if layout in (NODE, CONFIG_NODE) and not entry.caps.node_shardable:
        raise ValueError(
            f"protocol {spec.protocol!r} is not node-shardable: its registry entry "
            "sets Caps(node_shardable=False); run it dense or config-sharded, or "
            "re-register via repro.core.registry.register_protocol(...)"
        )
    if layout == CONFIG_NODE and not entry.caps.batch_node_shardable:
        raise ValueError(
            f"protocol {spec.protocol!r} cannot run on a 2-D config × node mesh: "
            "its registry entry sets Caps(batch_node_shardable=False) (configs "
            "cannot batch around its node collectives).  Shard the config axis "
            "only (layout='config'), or node-shard a single config "
            "(layout='node'), or re-register the protocol with different "
            "capabilities via repro.core.registry.register_protocol(...)"
        )

    if layout == NODE:
        return _plan_node(spec, node_shards, kernel_plane)

    devices = _resolve_devices(spec, need=layout in (CONFIG, CONFIG_NODE))
    if layout == DENSE and devices is not None and len(devices) > 1:
        raise ValueError(
            f"layout='dense' places at most one device, got {len(devices)}; "
            "use layout='config' (or devices='auto') to shard the config axis"
        )
    if layout == CONFIG and len(devices) < 2 and spec.layout == CONFIG:
        # explicit request for a config mesh on one device is fine — it just
        # degenerates to the dense program (run_grid_sharded's contract)
        layout = DENSE
    if layout == CONFIG_NODE:
        if not node_shards or node_shards < 2:
            raise ValueError(
                f"layout='config_node' needs node_shards >= 2, got {node_shards}"
            )
        if len(devices) % node_shards:
            raise ValueError(
                f"node_shards={node_shards} must divide the device count ({len(devices)})"
            )
        if spec.n_nodes % node_shards:
            raise ValueError(
                f"node_shards={node_shards} must divide n_nodes={spec.n_nodes}"
            )
    else:
        node_shards = None

    buckets = plan_buckets(
        list(spec.configs),
        coroutines=spec.coroutines,
        records_per_node=spec.records_per_node,
        ticks=spec.ticks,
    )
    planned = tuple(
        PlannedBucket(
            index=i,
            grid_spec=GridSpec(
                protocol=spec.protocol,
                workload=spec.workload,
                n_nodes=spec.n_nodes,
                coroutines=b.coroutines,
                records_per_node=b.records_per_node,
                ticks=b.ticks if b.ticks is not None else spec.ticks,
                warmup=spec.warmup,
                history_cap=spec.history_cap,
                mvcc_slots=spec.mvcc_slots,
                doorbell=spec.doorbell,
                tcp=spec.tcp,
                merge_stages=spec.merge_stages,
                kernel_plane=kernel_plane,
            ),
            bucket=b,
        )
        for i, b in enumerate(buckets)
    )
    cache = {DENSE: "grid", CONFIG: "grid_sharded", CONFIG_NODE: "grid2d"}[layout]
    return ExecutionPlan(
        spec=spec,
        layout=layout,
        devices=devices,
        node_shards=node_shards,
        buckets=planned,
        expected_compiles=len(planned),
        cache=cache,
        kernel_plane=kernel_plane,
    )


def _plan_node(
    spec: ExperimentSpec, node_shards: Optional[int], kernel_plane: str
) -> ExecutionPlan:
    """The single-config node-sharded layout (legacy ``run_cell_sharded``)."""
    if len(spec.configs) != 1:
        raise ValueError(
            f"layout='node' runs ONE config with the n_nodes axis on the mesh, "
            f"got {len(spec.configs)} configs; use layout='config_node' to also "
            "shard the config axis"
        )
    bad_axes = sorted(set(spec.configs[0]) & set(STATIC_AXES))
    if bad_axes:
        raise ValueError(
            f"layout='node' does not bucket static axes; move {bad_axes} to the "
            "ExperimentSpec grid defaults or use a dense/config layout"
        )
    if spec.devices is None or spec.devices == AUTO:
        devices = tuple(jax.devices())
        if node_shards is not None:
            if node_shards > len(devices):
                raise ValueError(
                    f"node_shards={node_shards} > visible devices ({len(devices)}); "
                    "set XLA_FLAGS=--xla_force_host_platform_device_count or --devices"
                )
            devices = devices[:node_shards]
    else:
        devices = tuple(spec.devices)
        if node_shards is not None and node_shards != len(devices):
            raise ValueError(
                f"node_shards={node_shards} conflicts with len(devices)={len(devices)}; "
                "pass one or the other"
            )
    if spec.n_nodes % len(devices):
        raise ValueError(
            f"node mesh: {len(devices)} device(s) must divide n_nodes={spec.n_nodes} "
            "(shards own whole simulated nodes)"
        )
    gs = GridSpec(
        protocol=spec.protocol,
        workload=spec.workload,
        n_nodes=spec.n_nodes,
        coroutines=spec.coroutines,
        records_per_node=spec.records_per_node,
        ticks=spec.ticks,
        warmup=spec.warmup,
        history_cap=spec.history_cap,
        mvcc_slots=spec.mvcc_slots,
        doorbell=spec.doorbell,
        tcp=spec.tcp,
        merge_stages=spec.merge_stages,
        kernel_plane=kernel_plane,
    )
    bucket = BucketPlan(
        indices=(0,),
        coroutines=spec.coroutines,
        records_per_node=spec.records_per_node,
        knob_configs=(dict(spec.configs[0]),),
        coroutines_active=None,
        records_active=None,
    )
    return ExecutionPlan(
        spec=spec,
        layout=NODE,
        devices=devices,
        node_shards=len(devices),
        buckets=(PlannedBucket(index=0, grid_spec=gs, bucket=bucket),),
        expected_compiles=1,
        cache="node",
        kernel_plane=kernel_plane,
    )


def execute(pl: ExecutionPlan) -> Results:
    """Run an :class:`ExecutionPlan`; returns :class:`Results`.

    Row schema matches the historical ``sweep.run_grid`` output (metrics from
    ``engine.summarize`` plus ``wall_s`` / ``grid_size`` / ``n_buckets`` /
    ``bucket`` / ``n_devices`` / ``n_node_shards`` / ``protocol`` /
    ``workload`` / ``hybrid`` / resolved static axes), so existing consumers
    and golden tests see identical dicts.
    """
    t0_all = time.time()
    if pl.layout == NODE:
        rows = [_execute_node(pl)]
        return Results(rows=rows, plan=pl, wall_s=round(time.time() - t0_all, 2))

    spec = pl.spec
    import jax.numpy as jnp

    rows: List[Optional[Dict]] = [None] * len(spec.configs)
    for pb in pl.buckets:
        b, gs = pb.bucket, pb.grid_spec
        knobs = make_knobs(spec.workload, b.knob_configs)
        if b.coroutines_active is not None:
            knobs = knobs._replace(
                coroutines_active=jnp.asarray(np.array(b.coroutines_active, np.int32))
            )
        if b.records_active is not None:
            knobs = knobs._replace(
                records_active=jnp.asarray(np.array(b.records_active, np.int32))
            )
        if b.ticks_active is not None:
            knobs = knobs._replace(
                ticks_active=jnp.asarray(np.array(b.ticks_active, np.int32))
            )
        t0 = time.time()
        if pl.layout == CONFIG_NODE:
            out = _sweep._run_sharded_2d(gs, knobs, list(pl.devices), pl.node_shards)
        elif pl.layout == CONFIG:
            out = _sweep._run_sharded(gs, knobs, list(pl.devices))
        else:
            if pl.devices is not None:  # honor an explicit single-device placement
                knobs = jax.device_put(knobs, pl.devices[0])
            out = {k: np.asarray(v) for k, v in _sweep._run_grid_jit(gs, knobs).items()}
        wall = round(time.time() - t0, 2)
        hy = np.asarray(knobs.hybrid)
        for g, idx in enumerate(b.indices):
            m = {k: v[g].tolist() for k, v in out.items()}
            m["wall_s"] = wall
            m["grid_size"] = len(spec.configs)
            m["n_buckets"] = len(pl.buckets)
            m["bucket"] = pb.index
            m["n_devices"] = pl.n_devices
            m["n_node_shards"] = pl.node_shards or 1
            m["protocol"], m["workload"] = spec.protocol, spec.workload
            m["hybrid"] = "".join(str(int(bit)) for bit in hy[g])
            m["coroutines"] = (
                b.coroutines if b.coroutines_active is None else b.coroutines_active[g]
            )
            m["records_per_node"] = (
                b.records_per_node if b.records_active is None else b.records_active[g]
            )
            m["ticks"] = gs.ticks if b.ticks_active is None else b.ticks_active[g]
            rows[idx] = m
    return Results(rows=rows, plan=pl, wall_s=round(time.time() - t0_all, 2))  # type: ignore[arg-type]


def _execute_node(pl: ExecutionPlan) -> Dict:
    spec = pl.spec
    pb = pl.buckets[0]
    knobs = make_knobs(spec.workload, pb.bucket.knob_configs)
    knobs = jax.tree_util.tree_map(lambda x: x[0], knobs)
    t0 = time.time()
    runner = _sweep._node_runner(pb.grid_spec, list(pl.devices))
    m = {k: np.asarray(v).tolist() for k, v in runner(knobs).items()}
    m["wall_s"] = round(time.time() - t0, 2)
    m["protocol"], m["workload"] = spec.protocol, spec.workload
    m["n_node_shards"] = len(pl.devices)
    hy = np.asarray(
        normalize_hybrid(pb.bucket.knob_configs[0].get("hybrid", (RPC,) * N_HYBRID_STAGES))
    )
    m["hybrid"] = "".join(str(int(b)) for b in hy)
    return m


def run(spec: ExperimentSpec) -> Results:
    """``execute(plan(spec))`` — the one-call front door."""
    return execute(plan(spec))


def compile_stats() -> Dict[str, int]:
    """Programs compiled so far per jit cache (-1 = no introspection in this
    JAX version).  Keys match :attr:`ExecutionPlan.cache`; perf_gate asserts
    the measured deltas against ``ExecutionPlan.expected_compiles``."""
    return {
        "grid": _sweep.compile_cache_size(),
        "grid_sharded": _sweep.sharded_compile_cache_size(),
        "grid2d": _sweep.grid2d_compile_count(),
        "node": _sweep.node_sharded_compile_count(),
    }


__all__ = [
    "AUTO",
    "DENSE",
    "CONFIG",
    "NODE",
    "CONFIG_NODE",
    "ExperimentSpec",
    "ExecutionPlan",
    "PlannedBucket",
    "Results",
    "plan",
    "execute",
    "run",
    "compile_stats",
    "all_hybrid_codes",
    "grid_product",
    "normalize_hybrid",
]
