"""Step builders: train (with gradient accumulation), prefill, decode."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import flags

from repro.configs.base import ArchConfig
from repro.models.decode import lm_decode_step, lm_prefill
from repro.models.lm import lm_loss
from repro.optim import make_optimizer
from repro.sharding import AxisRules


def build_train_step(cfg: ArchConfig, shd: AxisRules, opt_name: Optional[str] = None):
    """Returns (train_step, optimizer).

    train_step(params, opt_state, step, batch) -> (params, opt_state, metrics)
    batch: {"tokens": (B,S) or (n_micro, B_micro, S), "labels": same, ...}
    """
    optimizer = make_optimizer(opt_name or cfg.optimizer)
    acc_dtype = jnp.float32 if (opt_name or cfg.optimizer) == "adamw" else jnp.bfloat16

    def loss_fn(p, mb):
        return lm_loss(p, cfg, shd, mb)

    def train_step(params, opt_state, step, batch):
        tokens = batch["tokens"]
        if tokens.ndim == 2 or (cfg.encoder_decoder and tokens.ndim == 2):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            n_micro = tokens.shape[0]

            def mb_step(carry, mb):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(acc_dtype), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype), params)
            (g_acc, l_acc), _ = flags.scan(mb_step, (g0, jnp.zeros((), jnp.float32)), batch)
            grads = jax.tree.map(lambda g: g / n_micro, g_acc)
            loss = l_acc / n_micro
        new_params, new_opt, gnorm = optimizer.update(grads, opt_state, params, step)
        metrics = {"loss": loss, "grad_norm": gnorm, "step": step + 1}
        return new_params, new_opt, metrics

    return train_step, optimizer


def build_prefill(cfg: ArchConfig, shd: AxisRules):
    def prefill(params, batch):
        return lm_prefill(params, cfg, shd, batch)

    return prefill


def build_decode_step(cfg: ArchConfig, shd: AxisRules):
    def decode(params, cache, batch):
        return lm_decode_step(params, cfg, shd, cache, batch)

    return decode
