"""llama4-scout-17b-a16e — MoE 16 experts top-1 [hf:meta-llama/Llama-4-Scout-17B-16E].

Early-fusion multimodality is a frontend concern; the assigned cell specifies
the transformer backbone only (text tokens in input_specs).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    mlp_act="swiglu",
    norm="rmsnorm",
    n_experts=16,
    top_k=1,
    rope_theta=500_000.0,
    microbatch=8,
    serve_fsdp=True,  # expert weights exceed model-sharded HBM at serve time
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
SHARDING_OVERRIDES = {"fsdp": ("data",)}
