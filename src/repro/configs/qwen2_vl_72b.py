"""qwen2-vl-72b — VLM backbone with M-RoPE [arXiv:2409.12191].

Vision frontend (dynamic resolution ViT) is a STUB per the assignment:
input_specs() provides token ids plus per-token 3D M-RoPE positions
(temporal, height, width); vision tokens map to reserved vocab ids.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    mlp_act="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    microbatch=8,
    vision_stub=True,
    seq_parallel_prefill=False,  # measured 4x WORSE collectives under GSPMD auto-partitioning (EXPERIMENTS §Perf it.4 — refuted; needs manual ring attention)
    source="arXiv:2409.12191",
)
SHARDING_OVERRIDES = {"fsdp": ("data",)}
