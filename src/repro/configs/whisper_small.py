"""whisper-small — encoder-decoder audio backbone [arXiv:2212.04356].

Conv frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, 1500, d_model).  The model is small
(~240M); it replicates over the model axis except the FFN and shards
batch over data.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    mlp_act="gelu",
    mlp_bias=True,
    qkv_bias=True,
    norm="layernorm",
    encoder_decoder=True,
    n_enc_layers=12,
    enc_seq_len=1500,
    microbatch=4,
    source="arXiv:2212.04356",
)
# 51865 vocab and 12 heads are not 16-divisible -> auto-replicated.
SHARDING_OVERRIDES = {}
