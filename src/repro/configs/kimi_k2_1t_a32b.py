"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8 [arXiv:2501.kimi2].

Memory note (EXPERIMENTS.md §Dry-run): at 1.03T params even bf16
params+grads+momentum exceed a v5e-256 pod's HBM; config uses the
memory-lean bf16-momentum optimizer + 2D (expert x data) sharding and is
expected to *fit only on the 2-pod mesh* — the single-pod dry-run still
compiles and reports per-device bytes for the roofline table.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=2048,
    vocab_size=163840,
    mlp_act="swiglu",
    norm="rmsnorm",
    n_experts=384,
    top_k=8,
    rope_theta=50_000.0,
    microbatch=4,
    optimizer="momentum_bf16",
    serve_fsdp=True,  # expert weights exceed model-sharded HBM at serve time
    source="arXiv:2501.kimi2 (paper-table)",
)
SHARDING_OVERRIDES = {"fsdp": ("data",)}
