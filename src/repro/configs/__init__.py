"""Architecture config registry (``--arch <id>``)."""
from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.configs.base import SHAPES, ArchConfig, ShapeSpec, cell_supported  # noqa: F401

ARCH_MODULES = {
    "nemotron-4-15b": "nemotron_4_15b",
    "command-r-35b": "command_r_35b",
    "qwen2.5-32b": "qwen2_5_32b",
    "stablelm-1.6b": "stablelm_1_6b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "whisper-small": "whisper_small",
    "qwen2-vl-72b": "qwen2_vl_72b",
}

ARCH_IDS = tuple(ARCH_MODULES)


def get_config(arch_id: str) -> Tuple[ArchConfig, Dict]:
    """Returns (ArchConfig, sharding-rule overrides)."""
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[arch_id]}")
    return mod.CONFIG, getattr(mod, "SHARDING_OVERRIDES", {})


def reduced_config(arch_id: str) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    import dataclasses

    cfg, _ = get_config(arch_id)
    kw = dict(
        n_layers=min(cfg.n_layers, 4 if not cfg.block_pattern else 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        microbatch=1,
        remat="none",
    )
    if cfg.is_moe:
        # high capacity factor so reduced-config smoke tests are drop-free
        # (capacity dropping is batch-dependent and breaks decode-vs-forward
        # exact parity, which the smoke tests check)
        kw.update(n_experts=4, top_k=min(cfg.top_k, 2), capacity_factor=8.0)
    if cfg.ssm_state:
        kw.update(ssm_state=8, ssm_dt_rank=None)
    if cfg.block_pattern:
        kw.update(local_window=16, rnn_width=0, n_layers=5)  # 1 group + 2 tail
    if cfg.encoder_decoder:
        kw.update(n_enc_layers=2, n_layers=2, enc_seq_len=24)
    if cfg.mrope_sections:
        kw.update(mrope_sections=(4, 6, 6))
    return dataclasses.replace(cfg, **kw)
