"""command-r-35b — dense GQA, parallel block, no biases [hf:CohereForAI/c4ai-command-r-v01]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    mlp_act="swiglu",
    norm="layernorm_nobias",
    parallel_block=True,
    tie_embeddings=True,
    rope_theta=8_000_000.0,
    microbatch=8,
    seq_parallel_prefill=False,  # measured 4x WORSE collectives under GSPMD auto-partitioning (EXPERIMENTS §Perf it.4 — refuted; needs manual ring attention)
    source="hf:CohereForAI/c4ai-command-r-v01",
)
SHARDING_OVERRIDES = {"fsdp": ("data",)}
