"""falcon-mamba-7b — attention-free Mamba-1 SSM [arXiv:2410.05355]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,        # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,           # mamba blocks have no separate FFN
    vocab_size=65024,
    norm="rmsnorm",
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    microbatch=8,
    subquadratic=True,
    source="arXiv:2410.05355",
)
SHARDING_OVERRIDES = {"fsdp": ("data",)}
