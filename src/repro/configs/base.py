"""Architecture + shape configuration schema.

Every assigned architecture is described by an ``ArchConfig``; every assigned
input shape by a ``ShapeSpec``.  The (arch x shape) product defines the
dry-run / roofline cells.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architectures
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None  # default d_model // n_heads
    mlp_act: str = "swiglu"  # swiglu | sq_relu | gelu
    qkv_bias: bool = False
    mlp_bias: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm | layernorm_nobias
    parallel_block: bool = False  # command-r style parallel attn + ffn
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0  # fraction of head_dim rotated (stablelm: 0.25)
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # --- SSM (mamba1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: Optional[int] = None  # default ceil(d_model / 16)

    # --- hybrid (recurrentgemma): cycle of block kinds, e.g. 1 attn : 2 rglru
    block_pattern: Tuple[str, ...] = ()  # () => all "attn" (or "ssm" for ssm)
    local_window: int = 0  # sliding-window size for local attention blocks
    rnn_width: int = 0  # RG-LRU width (defaults to d_model)

    # --- encoder/decoder (whisper) ---
    encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_seq_len: int = 1_500  # stub frontend: precomputed frame embeddings

    # --- vlm ---
    vision_stub: bool = False

    # --- training knobs (per-arch defaults; overridable) ---
    remat: str = "full"  # full | save_attn | none
    microbatch: int = 1  # gradient-accumulation steps for train_4k
    optimizer: str = "adamw"  # adamw | momentum_bf16 (memory-lean for 1T)
    subquadratic: bool = False  # supports long_500k decode
    # prefill sharding strategy (EXPERIMENTS.md §Perf iteration 4): True =>
    # sequence-parallel prefill (weights replicated over `model`, sequence
    # sharded) instead of tensor parallelism — cheaper collectives for long
    # prompts on dense-attention archs.
    seq_parallel_prefill: bool = False
    # keep FSDP (data-axis) weight sharding at SERVE time (EXPERIMENTS.md
    # §Perf iteration 6): False => weights are model-sharded only for
    # prefill/decode, eliminating per-step weight all-gathers (FSDP is a
    # training optimization; it is a serving anti-pattern).  True only for
    # MoE archs whose expert weights cannot fit model-sharded HBM.
    serve_fsdp: bool = False

    # citation / provenance
    source: str = ""

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.ssm_state and self.ssm_dt_rank is None:
            object.__setattr__(self, "ssm_dt_rank", -(-self.d_model // 16))
        if self.block_pattern and not self.rnn_width:
            object.__setattr__(self, "rnn_width", self.d_model)

    # ------------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.ssm_state > 0 and not self.block_pattern

    @property
    def is_hybrid(self) -> bool:
        return bool(self.block_pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_rep(self) -> int:
        return self.n_heads // self.n_kv_heads

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind for the decoder stack."""
        if self.block_pattern:
            pat = self.block_pattern
            return tuple(pat[i % len(pat)] for i in range(self.n_layers))
        if self.ssm_state:
            return ("ssm",) * self.n_layers
        return ("attn",) * self.n_layers

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (embedding + stack + head)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        H, KV, Dh = self.n_heads, self.n_kv_heads, self.head_dim
        total = V * D  # embedding
        if not self.tie_embeddings:
            total += V * D  # lm head
        kinds = self.layer_kinds()
        for kind in kinds:
            total += 2 * D  # norms (approx; parallel block has 1)
            if kind == "attn":
                total += D * (H * Dh) + 2 * D * (KV * Dh) + (H * Dh) * D
                if self.qkv_bias:
                    total += (H + 2 * KV) * Dh
            elif kind == "ssm":
                di, N, R = self.d_inner, self.ssm_state, self.ssm_dt_rank
                total += D * 2 * di + di * self.ssm_conv  # in_proj + conv
                total += di * (R + 2 * N) + R * di + di  # x_proj, dt_proj
                total += di * N + di  # A_log, D
                total += di * D  # out_proj
            elif kind == "rglru":
                W = self.rnn_width
                total += 2 * D * W + W * D  # gate/in proj + out proj
                total += W * self.ssm_conv + 2 * W  # conv + lru params (approx)
            if kind != "ssm":  # ssm blocks have no separate FFN
                if self.is_moe:
                    n_mat = 3 if self.mlp_act == "swiglu" else 2
                    total += self.n_experts * n_mat * D * F
                    total += D * self.n_experts  # router
                else:
                    n_mat = 3 if self.mlp_act == "swiglu" else 2
                    total += n_mat * D * F
        if self.encoder_decoder:
            for _ in range(self.n_enc_layers):
                total += D * (H * Dh) * 2 + 2 * D * (KV * Dh) + 2 * D
                n_mat = 3 if self.mlp_act == "swiglu" else 2
                total += n_mat * D * F
            # decoder cross-attention
            total += self.n_layers * (D * (H * Dh) + 2 * D * (KV * Dh) + (H * Dh) * D + D)
        return total

    def active_param_count(self) -> int:
        """Active (per-token) parameters — differs from total for MoE."""
        if not self.is_moe:
            return self.param_count()
        D, F = self.d_model, self.d_ff
        n_mat = 3 if self.mlp_act == "swiglu" else 2
        inactive = self.n_layers * (self.n_experts - self.top_k) * n_mat * D * F
        return self.param_count() - inactive


def cell_supported(arch: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs, and why not if it doesn't."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, (
            "long_500k needs sub-quadratic token mixing; "
            f"{arch.name} is full-attention (skip per assignment rule)"
        )
    return True, ""
