"""recurrentgemma-2b — RG-LRU + local attention, 1 attn : 2 rglru [arXiv:2402.19427]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    mlp_act="geglu",
    norm="rmsnorm",
    block_pattern=("rglru", "rglru", "attn"),
    local_window=2048,
    rope_theta=10_000.0,
    microbatch=4,
    subquadratic=True,
    source="arXiv:2402.19427",
)
# heads (10) and kv_heads (1) do not divide the 16-way model axis: the
# shape-aware resolver auto-replicates them; FFN/RG-LRU widths still shard.
SHARDING_OVERRIDES = {}
