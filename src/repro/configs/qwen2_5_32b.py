"""qwen2.5-32b — dense GQA with QKV bias [hf:Qwen/Qwen2.5-32B family]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    mlp_act="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    microbatch=8,
    seq_parallel_prefill=False,  # measured 4x WORSE collectives under GSPMD auto-partitioning (EXPERIMENTS §Perf it.4 — refuted; needs manual ring attention)
    source="hf:Qwen/Qwen2.5-0.5B (family card)",
)
SHARDING_OVERRIDES = {"fsdp": ("data",)}
