"""stablelm-1.6b — dense MHA (kv=32), partial rotary 25% [hf:stabilityai/stablelm-2-1_6b]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    mlp_act="swiglu",
    norm="layernorm",
    rope_pct=0.25,
    rope_theta=10_000.0,
    microbatch=4,
    seq_parallel_prefill=False,  # measured 4x WORSE collectives under GSPMD auto-partitioning (EXPERIMENTS §Perf it.4 — refuted; needs manual ring attention)
    source="hf:stabilityai/stablelm-2-1_6b",
)
SHARDING_OVERRIDES = {}
