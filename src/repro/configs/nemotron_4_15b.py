"""nemotron-4-15b — dense GQA, squared-ReLU MLP [arXiv:2402.16819]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    mlp_act="sq_relu",
    norm="layernorm_nobias",
    rope_theta=10_000.0,
    rope_pct=0.5,
    microbatch=8,
    seq_parallel_prefill=False,  # measured 4x WORSE collectives under GSPMD auto-partitioning (EXPERIMENTS §Perf it.4 — refuted; needs manual ring attention)
    source="arXiv:2402.16819",
)
SHARDING_OVERRIDES = {"fsdp": ("data",)}
