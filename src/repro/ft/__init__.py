from repro.ft.runner import TrainRunner  # noqa: F401
