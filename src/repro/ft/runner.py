"""Fault-tolerant training runner.

What surviving 1000+ nodes actually requires, and where each piece lives:

  * checkpoint/restart — every N steps, atomic, includes optimizer AND data
    state (checkpoint/ckpt.py); restart resumes the exact token stream.
  * node failure — the step is a pure function of (params, opt, data_state);
    on any failure the runner restores the last checkpoint and continues.
    Failure *injection* here raises at a chosen step to prove the path.
  * elastic scaling — restore is resharding-aware: leaves are stored whole
    with the save-time mesh recorded, so `remesh()` restores the same
    checkpoint onto a larger/smaller mesh and re-resolves shardings.
  * straggler mitigation — data assignment is deterministic in
    (step, host_id) (data/pipeline.py), so a slow/dead host's shard can be
    recomputed by any survivor; at the step level, the bulk-synchronous
    collective acts as the barrier and the mitigation is *re-mesh without
    the straggler* (elastic path above).  We additionally expose a
    `skip_stragglers` gradient mode: scale the gradient by the fraction of
    contributing microbatches (documented accuracy trade-off).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data.pipeline import DataState


class TrainRunner:
    def __init__(
        self,
        train_step: Callable,
        init_state: Callable,  # () -> (params, opt_state)
        next_batch: Callable,  # (DataState) -> (DataState, batch)
        data_init: Callable,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 50,
        fail_at: Optional[int] = None,  # failure injection (testing)
    ):
        self.train_step = train_step
        self.init_state = init_state
        self.next_batch = next_batch
        self.data_init = data_init
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.fail_at = fail_at
        self._failed_once = False

    # ------------------------------------------------------------------
    def _bundle(self, params, opt_state, data_state, step):
        return {
            "params": params,
            "opt": opt_state,
            "data": {"step": data_state.step, "seed": jnp.int32(data_state.seed)},
            "step": jnp.int32(step),
        }

    def _restore(self, proto):
        step, tree = restore_checkpoint(self.ckpt_dir, proto)
        ds = DataState(tree["data"]["step"], int(tree["data"]["seed"]))
        return int(tree["step"]), tree["params"], tree["opt"], ds

    # ------------------------------------------------------------------
    def run(self, n_steps: int, log_every: int = 10) -> Dict:
        params, opt_state = self.init_state()
        data_state = self.data_init()
        start = 0
        proto = jax.tree.map(lambda x: np_like(x), self._bundle(params, opt_state, data_state, 0))
        if self.ckpt_dir and latest_step(self.ckpt_dir) is not None:
            start, params, opt_state, data_state = self._restore(proto)
            print(f"[ft] resumed from checkpoint at step {start}", flush=True)

        losses = []
        step = start
        while step < n_steps:
            try:
                if self.fail_at is not None and step == self.fail_at and not self._failed_once:
                    self._failed_once = True
                    raise RuntimeError(f"[ft] injected node failure at step {step}")
                data_state, batch = self.next_batch(data_state)
                params, opt_state, metrics = self.train_step(
                    params, opt_state, jnp.int32(step), batch
                )
                loss = float(metrics["loss"])
                losses.append(loss)
                if step % log_every == 0:
                    print(f"[train] step={step} loss={loss:.4f} gnorm={float(metrics['grad_norm']):.3f}", flush=True)
                step += 1
                if self.ckpt_dir and step % self.ckpt_every == 0:
                    save_checkpoint(
                        self.ckpt_dir, step, self._bundle(params, opt_state, data_state, step)
                    )
            except RuntimeError as e:
                if "injected node failure" not in str(e) or not self.ckpt_dir:
                    raise
                print(f"{e} -> restoring latest checkpoint", flush=True)
                step, params, opt_state, data_state = self._restore(proto)
        if self.ckpt_dir:
            save_checkpoint(self.ckpt_dir, step, self._bundle(params, opt_state, data_state, step))
        return {"final_step": step, "losses": losses, "params": params, "opt": opt_state}


def np_like(x):
    return x


def remesh_restore(ckpt_dir: str, proto, new_shardings):
    """Elastic scaling: restore the latest checkpoint onto a different mesh
    (leaves stored whole; shardings re-resolved for the new topology)."""
    return restore_checkpoint(ckpt_dir, proto, shardings=new_shardings)
