"""Logical-axis sharding rules + param/spec plumbing.

Model code annotates every parameter and activation with *logical* axis names
("batch", "heads", "ff", "expert", ...).  A per-(arch, mesh) rule table maps
logical names to mesh axes.  Resolution is shape-aware: a logical axis whose
dimension is not divisible by the mapped mesh-axis size is silently dropped
(replicated) — this is how e.g. whisper's 12 heads stay replicated on a
16-way model axis while its 3072-wide FFN still shards.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class Param:
    """A parameter leaf: value + logical partition spec.

    Deliberately NOT a registered pytree node, so trees of Params can be
    unzipped with ``tree_map(..., is_leaf=...)``.
    """

    __slots__ = ("value", "spec")

    def __init__(self, value, spec: P):
        self.value = value
        self.spec = spec

    def __repr__(self):
        return f"Param({getattr(self.value, 'shape', self.value)}, {self.spec})"


def _is_param(x) -> bool:
    return isinstance(x, Param)


def unzip_params(tree):
    """Split a Param-leaved tree into (values, logical_specs)."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=_is_param)
    specs = jax.tree.map(lambda p: p.spec, tree, is_leaf=_is_param)
    return values, specs


# ---------------------------------------------------------------------------
# Default logical -> mesh-axis rules
# ---------------------------------------------------------------------------

# Single-pod production mesh: ("data", "model"); multi-pod adds leading "pod".
DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("data",),  # ("pod","data") resolved automatically on pod meshes
    "seq": None,  # activation sequence axis (context parallelism if set)
    "embed": None,  # d_model dim of activations / params
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "expert": ("model",),
    "d_inner": ("model",),  # mamba inner channels
    "rnn": ("model",),  # rg-lru width
    "kv_seq": ("model",),  # decode KV-cache sequence sharding (flash-decoding)
    "fsdp": None,  # param dim for ZeRO/FSDP-style sharding (per-arch opt-in)
    "replicated": None,
}


def merge_rules(overrides: Optional[Dict[str, Any]]) -> Dict[str, Any]:
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    return rules


class AxisRules:
    """Resolves logical PartitionSpecs against a concrete mesh.

    mesh=None => everything replicated (single-device smoke tests).
    """

    def __init__(self, mesh: Optional[Mesh], rules: Optional[Dict[str, Any]] = None):
        self.mesh = mesh
        self.rules = merge_rules(rules)
        self.axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else {}
        self.has_pod = "pod" in self.axis_sizes

    # -- resolution --------------------------------------------------------
    def _mesh_axes_for(self, logical: Optional[str]) -> Tuple[str, ...]:
        if logical is None:
            return ()
        axes = self.rules.get(logical, None)
        if axes is None:
            return ()
        if isinstance(axes, str):
            axes = (axes,)
        axes = tuple(axes)
        # batch composes with the pod axis on multi-pod meshes
        if logical == "batch" and self.has_pod and "pod" not in axes:
            axes = ("pod",) + axes
        return tuple(a for a in axes if a in self.axis_sizes)

    def resolve(self, spec: P, shape: Optional[Sequence[int]] = None) -> P:
        """Logical spec -> mesh spec, dropping non-divisible axes."""
        if self.mesh is None:
            return P()
        out, used = [], set()
        for i, entry in enumerate(spec):
            names = entry if isinstance(entry, tuple) else (entry,)
            mesh_axes = []
            for nm in names:
                for ax in self._mesh_axes_for(nm):
                    if ax in used:
                        continue
                    mesh_axes.append(ax)
            if shape is not None and mesh_axes:
                total = int(np.prod([self.axis_sizes[a] for a in mesh_axes]))
                # greedily drop trailing axes until divisible
                while mesh_axes and shape[i] % total != 0:
                    dropped = mesh_axes.pop()
                    total //= self.axis_sizes[dropped]
            used.update(mesh_axes)
            if not mesh_axes:
                out.append(None)
            elif len(mesh_axes) == 1:
                out.append(mesh_axes[0])
            else:
                out.append(tuple(mesh_axes))
        return P(*out)

    def sharding(self, spec: P, shape: Optional[Sequence[int]] = None) -> NamedSharding:
        assert self.mesh is not None
        return NamedSharding(self.mesh, self.resolve(spec, shape))

    # -- activation constraints --------------------------------------------
    def constrain(self, x: jnp.ndarray, *logical: Optional[str]) -> jnp.ndarray:
        if self.mesh is None:
            return x
        spec = self.resolve(P(*logical), x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    # -- param tree resolution ----------------------------------------------
    def resolve_tree(self, shapes_tree, specs_tree):
        """tree of shapes x tree of logical specs -> tree of NamedShardings."""
        return jax.tree.map(
            lambda sh, sp: self.sharding(sp, tuple(sh.shape) if hasattr(sh, "shape") else tuple(sh)),
            shapes_tree,
            specs_tree,
        )


# ---------------------------------------------------------------------------
# Deterministic per-name key derivation
# ---------------------------------------------------------------------------

import zlib


def name_key(key: jax.Array, name: str) -> jax.Array:
    return jax.random.fold_in(key, zlib.crc32(name.encode()) & 0x7FFFFFFF)


def dense_init(key, name, shape, spec, dtype=jnp.float32, scale=None) -> Param:
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    if scale is None:
        scale = 1.0 / np.sqrt(max(fan_in, 1))
    k = name_key(key, name)
    v = (jax.random.truncated_normal(k, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)
    return Param(v, spec)


def zeros_init(name, shape, spec, dtype=jnp.float32) -> Param:
    return Param(jnp.zeros(shape, dtype), spec)


def ones_init(name, shape, spec, dtype=jnp.float32) -> Param:
    return Param(jnp.ones(shape, dtype), spec)
