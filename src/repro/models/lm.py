"""Unified LM stack covering all assigned architecture families.

One parameterized decoder (plus optional encoder) built from block kinds:
  "attn"   — GQA attention (+ optional sliding window) + FFN/MoE
  "ssm"    — Mamba-1 selective SSM (no separate FFN)
  "rglru"  — RG-LRU recurrent block + FFN

Forward entry points:
  lm_loss(...)          train-time causal LM loss over the full sequence
  lm_prefill(...)       full forward building a KV/state cache, returns last logits
  lm_decode_step(...)   one-token decode against the cache (seq-sharded KV)
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import flags
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.layers import attention as attn_lib
from repro.layers.common import apply_mrope, apply_norm, apply_rope, init_norm, sinusoidal_positions
from repro.layers.mlp import apply_mlp, init_mlp
from repro.layers.moe import apply_moe, init_moe
from repro.layers.rglru import apply_rglru, init_rglru
from repro.layers.ssm import apply_ssm, init_ssm
from repro.sharding import AxisRules, Param, dense_init, name_key, unzip_params

try:
    from jax import shard_map  # type: ignore
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ArchConfig, kind: str, dtype):
    if kind == "ssm":
        return {"norm": init_norm(cfg.norm, cfg.d_model, dtype), "ssm": init_ssm(key, cfg, dtype)}
    if kind == "rglru":
        return {
            "norm1": init_norm(cfg.norm, cfg.d_model, dtype),
            "rglru": init_rglru(key, cfg, dtype),
            "norm2": init_norm(cfg.norm, cfg.d_model, dtype),
            "mlp": init_mlp(key, cfg, dtype),
        }
    # attention block
    ffn = init_moe(key, cfg, dtype) if cfg.is_moe else init_mlp(key, cfg, dtype)
    ffn_name = "moe" if cfg.is_moe else "mlp"
    if cfg.parallel_block:
        return {
            "norm": init_norm(cfg.norm, cfg.d_model, dtype),
            "attn": attn_lib.init_attn(key, cfg, dtype),
            ffn_name: ffn,
        }
    return {
        "norm1": init_norm(cfg.norm, cfg.d_model, dtype),
        "attn": attn_lib.init_attn(key, cfg, dtype),
        "norm2": init_norm(cfg.norm, cfg.d_model, dtype),
        ffn_name: ffn,
    }


def _init_dec_layer(key, cfg: ArchConfig, dtype):
    """Whisper decoder layer: self-attn + cross-attn + FFN."""
    return {
        "norm1": init_norm(cfg.norm, cfg.d_model, dtype),
        "attn": attn_lib.init_attn(key, cfg, dtype),
        "norm_x": init_norm(cfg.norm, cfg.d_model, dtype),
        "xattn": attn_lib.init_attn(key, cfg, dtype, cross=True),
        "norm2": init_norm(cfg.norm, cfg.d_model, dtype),
        "mlp": init_mlp(key, cfg, dtype),
    }


def _stack_init(key, n: int, init_fn):
    """vmap a Param-returning init over n layers; prepend layer dim to specs."""
    keys = jax.random.split(key, n)
    captured = {}

    def vals_fn(k):
        vals, specs = unzip_params(init_fn(k))
        captured["specs"] = specs
        return vals

    jax.eval_shape(vals_fn, keys[0])  # capture specs without allocating
    values = jax.vmap(vals_fn)(keys)
    specs = jax.tree.map(lambda s: P(None, *tuple(s)), captured["specs"])
    return jax.tree.map(Param, values, specs)


def init_lm(key, cfg: ArchConfig, dtype=jnp.float32):
    V, D = cfg.vocab_size, cfg.d_model
    # embed table: vocab-sharded only (it is small per device already; an
    # extra fsdp axis on D would force gathers in the sharded lookup)
    params: Dict[str, Any] = {
        "embed": dense_init(key, "embed", (V, D), P("vocab", None), dtype, scale=0.02),
        "final_norm": init_norm(cfg.norm, D, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(key, "lm_head", (D, V), P(("embed", "fsdp"), "vocab"), dtype)

    kinds = cfg.layer_kinds()
    if cfg.is_hybrid:
        pat = cfg.block_pattern
        n_full = cfg.n_layers // len(pat)
        rem = cfg.n_layers % len(pat)
        params["groups"] = {
            f"g{j}_{k}": _stack_init(
                name_key(key, f"grp{j}"), n_full, lambda kk, kind=k: _init_layer(kk, cfg, kind, dtype)
            )
            for j, k in enumerate(pat)
        }
        params["tail"] = [
            _init_layer(name_key(key, f"tail{i}"), cfg, pat[i], dtype) for i in range(rem)
        ]
    else:
        kind = kinds[0]
        params["layers"] = _stack_init(
            name_key(key, "layers"), cfg.n_layers, lambda kk: _init_layer(kk, cfg, kind, dtype)
        )

    if cfg.encoder_decoder:
        params["enc_layers"] = _stack_init(
            name_key(key, "enc"), cfg.n_enc_layers, lambda kk: _init_layer(kk, cfg, "attn", dtype)
        )
        params["enc_norm"] = init_norm(cfg.norm, D, dtype)
        params["dec_layers"] = _stack_init(
            name_key(key, "dec"), cfg.n_layers, lambda kk: _init_dec_layer(kk, cfg, dtype)
        )
        # NOTE: whisper proper uses a learned decoder position table (448
        # entries); the assigned 32k/500k shapes exceed any learned table, so
        # we use sinusoidal decoder positions (documented deviation).
        del params["layers"]
    return params


# ---------------------------------------------------------------------------
# Block bodies (full-sequence)
# ---------------------------------------------------------------------------


def _rope(cfg: ArchConfig, x, positions):
    if cfg.mrope_sections is not None:
        return apply_mrope(x, positions, cfg.mrope_sections, cfg.rope_theta)
    return apply_rope(x, positions, cfg.rope_pct, cfg.rope_theta)


def _attn_full(lp, cfg: ArchConfig, shd: AxisRules, x, positions, *, causal=True, window=0, use_rope=True):
    q, k, v = attn_lib._project_qkv(lp, cfg, x)
    if use_rope:
        q = _rope(cfg, q, positions)
        k = _rope(cfg, k, positions)
    # Explicit layouts (perf: see EXPERIMENTS.md §Perf iteration 1): Q shards
    # on heads; K/V stay REPLICATED over `model` when kv_heads doesn't divide
    # it — without this, GSPMD shards K/V on head_dim and every attention
    # score einsum becomes a partial-sum + all-reduce of (B,H,S,chunk).
    q = shd.constrain(q, "batch", None, "heads", None)
    k = shd.constrain(k, "batch", None, "kv_heads", None)
    v = shd.constrain(v, "batch", None, "kv_heads", None)
    k = attn_lib.repeat_kv(k, cfg.n_rep)
    v = attn_lib.repeat_kv(v, cfg.n_rep)
    S = x.shape[1]
    if window and S > window:
        out = attn_lib.local_attention_xla(q, k, v, window=window, causal=causal)
    elif flags.USE_PALLAS_ATTENTION and not window and jax.default_backend() == "tpu":
        from repro.kernels.flash_attention import flash_attention as _fa

        out = _fa(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            causal=causal, interpret=False,
        ).transpose(0, 2, 1, 3)
    elif S <= 512:
        out = attn_lib.naive_attention(q, k, v, causal=causal, window=window)
    else:
        out = attn_lib.flash_attention_xla(q, k, v, causal=causal, window=window)
    return attn_lib._out_proj(lp, out, x.dtype)


def _ffn(lp, cfg: ArchConfig, shd, x):
    if cfg.is_moe:
        return apply_moe(lp["moe"], cfg, shd, x)
    return apply_mlp(lp["mlp"], cfg, shd, x)


def _block_full(lp, cfg: ArchConfig, shd, kind: str, x, positions, *, causal=True):
    """One decoder block over a full sequence. x (B,S,D)."""
    if kind == "ssm":
        return x + apply_ssm(lp["ssm"], cfg, shd, apply_norm(cfg.norm, lp["norm"], x))
    if kind == "rglru":
        x = x + apply_rglru(lp["rglru"], cfg, shd, apply_norm(cfg.norm, lp["norm1"], x))
        return x + apply_mlp(lp["mlp"], cfg, shd, apply_norm(cfg.norm, lp["norm2"], x))
    window = cfg.local_window if (cfg.is_hybrid and kind == "attn") else 0
    if cfg.parallel_block:
        h = apply_norm(cfg.norm, lp["norm"], x)
        return x + _attn_full(lp["attn"], cfg, shd, h, positions, causal=causal, window=window) + _ffn(
            lp, cfg, shd, h
        )
    h = apply_norm(cfg.norm, lp["norm1"], x)
    x = x + _attn_full(lp["attn"], cfg, shd, h, positions, causal=causal, window=window)
    x = shd.constrain(x, "batch", "seq", None)
    return x + _ffn(lp, cfg, shd, apply_norm(cfg.norm, lp["norm2"], x))


def _remat(f, cfg: ArchConfig):
    if cfg.remat == "none":
        return f
    if cfg.remat == "save_attn":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(f, policy=policy)
    return jax.checkpoint(f)


def _run_stack(params, cfg: ArchConfig, shd, x, positions, *, causal=True):
    """Scan the decoder stack over x (B,S,D)."""
    if cfg.is_hybrid:
        pat = cfg.block_pattern
        group_stacks = [params["groups"][f"g{j}_{k}"] for j, k in enumerate(pat)]

        def group_body(h, lps):
            for j, kind in enumerate(pat):
                h = _block_full(lps[j], cfg, shd, kind, h, positions, causal=causal)
            return h, None

        vals = [unzip_params(g)[0] if _has_params(g) else g for g in group_stacks]
        x, _ = flags.scan(_remat(group_body, cfg), x, tuple(vals))
        for i, lp in enumerate(params["tail"]):
            lpv = unzip_params(lp)[0] if _has_params(lp) else lp
            x = _block_full(lpv, cfg, shd, pat[i], x, positions, causal=causal)
        return x

    kind = cfg.layer_kinds()[0]

    def body(h, lp):
        return _block_full(lp, cfg, shd, kind, h, positions, causal=causal), None

    stacked = params["layers"]
    vals = unzip_params(stacked)[0] if _has_params(stacked) else stacked
    x, _ = flags.scan(_remat(body, cfg), x, vals)
    return x


def _has_params(tree) -> bool:
    found = [False]

    def chk(x):
        if isinstance(x, Param):
            found[0] = True
        return x

    jax.tree.map(chk, tree, is_leaf=lambda x: isinstance(x, Param))
    return found[0]


def strip_params(tree):
    """Param-leaved tree -> raw value tree (no-op if already raw)."""
    return unzip_params(tree)[0] if _has_params(tree) else tree


# ---------------------------------------------------------------------------
# Embedding / logits / loss
# ---------------------------------------------------------------------------


def embed_tokens(params, cfg: ArchConfig, shd, tokens):
    """Vocab-sharded lookup: local masked gather + psum over the vocab axis.

    Without this, GSPMD all-gathers the whole table per lookup (observed in
    the decode dry-runs — EXPERIMENTS.md §Perf iteration 2).
    """
    emb = params["embed"]
    if shd.mesh is not None:
        vocab_ax = shd.resolve(P("vocab"), (cfg.vocab_size,))[0]
        if vocab_ax is not None:
            batch_ax = shd.resolve(P("batch"), (tokens.shape[0],))[0]
            v_local = cfg.vocab_size // shd.axis_sizes[
                vocab_ax if isinstance(vocab_ax, str) else vocab_ax[0]
            ]
            ax_name = vocab_ax if isinstance(vocab_ax, str) else vocab_ax[0]

            def body(emb_l, tok_l):
                v0 = jax.lax.axis_index(ax_name) * v_local
                loc = tok_l - v0
                mine = (loc >= 0) & (loc < v_local)
                x = emb_l[jnp.clip(loc, 0, v_local - 1)]
                x = jnp.where(mine[..., None], x, 0)
                return jax.lax.psum(x, ax_name)

            x = shard_map(
                body,
                mesh=shd.mesh,
                in_specs=(P(ax_name, None), P(batch_ax, None)),
                out_specs=P(batch_ax, None, None),
            )(emb, tokens)
            return shd.constrain(x, "batch", "seq", None)
    x = jnp.take(emb, tokens, axis=0)
    return shd.constrain(x, "batch", "seq", None)


def logits_fn(params, cfg: ArchConfig, shd, x):
    x = apply_norm(cfg.norm, params["final_norm"], x)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    return shd.constrain(logits, "batch", "seq", "vocab")


def xent_loss(logits, labels, mask=None, shd: Optional[AxisRules] = None):
    """Streaming-safe cross-entropy with vocab possibly sharded."""
    lf = logits.astype(jnp.float32)
    m = lf.max(-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, lf.shape[-1], dtype=lf.dtype)
    if shd is not None:
        onehot = shd.constrain(onehot, "batch", "seq", "vocab")
    gold = jnp.sum(lf * onehot, axis=-1)
    nll = lse - gold
    if mask is None:
        return nll.mean()
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Whisper encoder (stub conv frontend: inputs are precomputed frame embeds)
# ---------------------------------------------------------------------------


def encode_audio(params, cfg: ArchConfig, shd, frames):
    """frames (B, T_enc, D) -> encoder states."""
    pos = sinusoidal_positions(frames.shape[1], cfg.d_model).astype(frames.dtype)
    x = frames + pos[None]
    positions = jnp.arange(frames.shape[1])[None]

    def body(h, lp):
        return _block_full(lp, cfg, shd, "attn", h, positions, causal=False), None

    x, _ = flags.scan(_remat(body, cfg), x, strip_params(params["enc_layers"]))
    return apply_norm(cfg.norm, params["enc_norm"], x)


def _dec_block_full(lp, cfg: ArchConfig, shd, x, enc, positions):
    h = apply_norm(cfg.norm, lp["norm1"], x)
    x = x + _attn_full(lp["attn"], cfg, shd, h, positions, causal=True, use_rope=False)
    h = apply_norm(cfg.norm, lp["norm_x"], x)
    q, k, v = attn_lib._project_qkv(lp["xattn"], cfg, h, kv_x=enc)
    k = attn_lib.repeat_kv(k, cfg.n_rep)
    v = attn_lib.repeat_kv(v, cfg.n_rep)
    out = attn_lib.flash_attention_xla(q, k, v, causal=False)
    x = x + attn_lib._out_proj(lp["xattn"], out, x.dtype)
    return x + apply_mlp(lp["mlp"], cfg, shd, apply_norm(cfg.norm, lp["norm2"], x))


def _run_decoder_encdec(params, cfg: ArchConfig, shd, x, enc, positions):
    S = x.shape[1]
    x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]

    def body(h, lp):
        return _dec_block_full(lp, cfg, shd, h, enc, positions), None

    x, _ = flags.scan(_remat(body, cfg), x, strip_params(params["dec_layers"]))
    return x


# ---------------------------------------------------------------------------
# Public full-sequence entry points
# ---------------------------------------------------------------------------


def lm_hidden(params, cfg: ArchConfig, shd: AxisRules, batch: Dict[str, jnp.ndarray]):
    """Backbone forward -> final hidden states (B,S,D)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = embed_tokens(params, cfg, shd, tokens)
    if cfg.encoder_decoder:
        enc = encode_audio(params, cfg, shd, batch["frames"])
        x = _run_decoder_encdec(params, cfg, shd, x, enc, positions)
    else:
        x = _run_stack(params, cfg, shd, x, positions, causal=True)
    return x


def lm_apply(params, cfg: ArchConfig, shd: AxisRules, batch: Dict[str, jnp.ndarray]):
    """Full forward -> logits (B,S,V). batch: tokens (+positions/frames)."""
    return logits_fn(params, cfg, shd, lm_hidden(params, cfg, shd, batch))


def lm_loss(params, cfg: ArchConfig, shd: AxisRules, batch, loss_chunk: int = 1024) -> jnp.ndarray:
    """Causal LM loss with SEQUENCE-CHUNKED head+xent: the (B,S,V) logits
    tensor is never materialized (EXPERIMENTS.md §Perf iteration 3) — each
    chunk's logits are recomputed in the backward pass (checkpointed), which
    trades one extra lm_head matmul for ~B*S*V*8 bytes of peak temp."""
    labels = batch["labels"]
    x = lm_hidden(params, cfg, shd, batch)
    xs, ys = x[:, :-1], labels[:, 1:]
    B, S1, D = xs.shape
    chunk = min(loss_chunk, S1)
    n = -(-S1 // chunk)
    pad = n * chunk - S1
    mask = jnp.pad(jnp.ones((B, S1), jnp.float32), ((0, 0), (0, pad)))
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        ys = jnp.pad(ys, ((0, 0), (0, pad)))
    xs = xs.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    ys = ys.reshape(B, n, chunk).transpose(1, 0, 2)
    mask = mask.reshape(B, n, chunk).transpose(1, 0, 2)
    head = {"final_norm": params["final_norm"], "embed": params["embed"]}
    if not cfg.tie_embeddings:
        head["lm_head"] = params["lm_head"]

    @jax.checkpoint
    def chunk_nll(head_p, xc, yc, mc):
        logits = logits_fn(head_p, cfg, shd, xc)
        lf = logits.astype(jnp.float32)
        m = lf.max(-1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
        onehot = shd.constrain(jax.nn.one_hot(yc, lf.shape[-1], dtype=lf.dtype), "batch", None, "vocab")
        gold = jnp.sum(lf * onehot, axis=-1)
        return ((lse - gold) * mc).sum()

    def body(acc, inp):
        xc, yc, mc = inp
        return acc + chunk_nll(head, xc, yc, mc), None

    total, _ = flags.scan(body, jnp.zeros((), jnp.float32), (xs, ys, mask))
    return total / jnp.maximum(mask.sum(), 1.0)
