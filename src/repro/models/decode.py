"""Prefill + single-token decode with caches (KV / SSM state / RG-LRU state).

Cache layout (Param-leaved at construction so specs travel with values):
  attn stacks:   {"k": (L,B,S,KV,Dh), "v": ...}  — S sharded over `kv_seq`
  ssm stacks:    {"h": (L,B,di,N), "conv": (L,B,K-1,di)}
  hybrid:        per-group caches; attention groups use ring (window) caches
  enc-dec:       self cache + precomputed per-layer cross K/V
  plus "len": scalar int32 (tokens already in cache).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import flags
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.layers import attention as attn_lib
from repro.layers.common import apply_norm, sinusoidal_positions
from repro.layers.mlp import apply_mlp
from repro.layers.rglru import apply_rglru, apply_rglru_step
from repro.layers.ssm import apply_ssm, apply_ssm_step
from repro.models.lm import (
    _ffn,
    _rope,
    embed_tokens,
    encode_audio,
    logits_fn,
    strip_params,
)
from repro.sharding import AxisRules, Param


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def _attn_cache_struct(cfg: ArchConfig, n_layers: int, batch: int, s_max: int, dtype):
    KV, Dh = cfg.n_kv_heads, cfg.head_dim
    shape = (n_layers, batch, s_max, KV, Dh)
    spec = P(None, "batch", "kv_seq", None, None)
    return {
        "k": Param(jnp.zeros(shape, dtype), spec),
        "v": Param(jnp.zeros(shape, dtype), spec),
    }


def _ssm_cache_struct(cfg: ArchConfig, n_layers: int, batch: int, dtype):
    di, N, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {
        "h": Param(jnp.zeros((n_layers, batch, di, N), jnp.float32), P(None, "batch", "d_inner", None)),
        "conv": Param(jnp.zeros((n_layers, batch, K - 1, di), dtype), P(None, "batch", None, "d_inner")),
    }


def _rglru_cache_struct(cfg: ArchConfig, n_layers: int, batch: int, dtype):
    W, K = cfg.rnn_width, cfg.ssm_conv
    return {
        "h": Param(jnp.zeros((n_layers, batch, W), jnp.float32), P(None, "batch", "rnn")),
        "conv": Param(jnp.zeros((n_layers, batch, K - 1, W), dtype), P(None, "batch", None, "rnn")),
    }


def init_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=jnp.float32):
    """Empty cache (Param-leaved tree: values + logical specs)."""
    cache: Dict[str, Any] = {"len": Param(jnp.zeros((), jnp.int32), P())}
    if cfg.encoder_decoder:
        cache["self"] = _attn_cache_struct(cfg, cfg.n_layers, batch, s_max, dtype)
        KV, Dh = cfg.n_kv_heads, cfg.head_dim
        xshape = (cfg.n_layers, batch, cfg.enc_seq_len, KV, Dh)
        xspec = P(None, "batch", None, None, None)
        cache["cross_k"] = Param(jnp.zeros(xshape, dtype), xspec)
        cache["cross_v"] = Param(jnp.zeros(xshape, dtype), xspec)
        return cache
    if cfg.is_hybrid:
        pat = cfg.block_pattern
        rem = cfg.n_layers % len(pat)
        groups = {}
        for j, kind in enumerate(pat):
            if kind == "attn":
                w = min(cfg.local_window or s_max, s_max)
                groups[f"g{j}_attn"] = _attn_cache_struct(cfg, n_full, batch, w, dtype)
            elif kind == "rglru":
                groups[f"g{j}_rglru"] = _rglru_cache_struct(cfg, n_full, batch, dtype)
            else:
                groups[f"g{j}_ssm"] = _ssm_cache_struct(cfg, n_full, batch, dtype)
        cache["groups"] = groups
        cache["tail"] = [
            (
                _attn_cache_struct(cfg, 1, batch, min(cfg.local_window or s_max, s_max), dtype)
                if pat[i] == "attn"
                else _rglru_cache_struct(cfg, 1, batch, dtype)
                if pat[i] == "rglru"
                else _ssm_cache_struct(cfg, 1, batch, dtype)
            )
            for i in range(rem)
        ]
        return cache
    kind = cfg.layer_kinds()[0]
    if kind == "ssm":
        cache["layers"] = _ssm_cache_struct(cfg, cfg.n_layers, batch, dtype)
    else:
        cache["layers"] = _attn_cache_struct(cfg, cfg.n_layers, batch, s_max, dtype)
    return cache


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def _pad_entry(entry, target):
    """Pad a {"k","v"} cache entry along the sequence dim to `target` slots."""
    S = entry["k"].shape[1]
    if target is None or target <= S:
        return entry
    pad = ((0, 0), (0, target - S), (0, 0), (0, 0))
    return {k: jnp.pad(v, pad) for k, v in entry.items()}


def _attn_block_prefill(lp, cfg: ArchConfig, shd, kind, x, positions, window, pad_to=None):
    """Block forward that also returns this layer's cache entry."""
    if kind == "ssm":
        h, st = apply_ssm(lp["ssm"], cfg, shd, apply_norm(cfg.norm, lp["norm"], x), return_state=True)
        return x + h, st
    if kind == "rglru":
        h, st = apply_rglru(lp["rglru"], cfg, shd, apply_norm(cfg.norm, lp["norm1"], x), return_state=True)
        x = x + h
        return x + apply_mlp(lp["mlp"], cfg, shd, apply_norm(cfg.norm, lp["norm2"], x)), st

    hin = apply_norm(cfg.norm, lp["norm"] if cfg.parallel_block else lp["norm1"], x)
    q, k, v = attn_lib._project_qkv(lp["attn"], cfg, hin)
    q = _rope(cfg, q, positions)
    k = _rope(cfg, k, positions)
    # see EXPERIMENTS.md §Perf iteration 1 (layout pinning)
    q = shd.constrain(q, "batch", None, "heads", None)
    k = shd.constrain(k, "batch", None, "kv_heads", None)
    v = shd.constrain(v, "batch", None, "kv_heads", None)
    kx = attn_lib.repeat_kv(k, cfg.n_rep)
    vx = attn_lib.repeat_kv(v, cfg.n_rep)
    S = x.shape[1]
    if window and S > window:
        out = attn_lib.local_attention_xla(q, kx, vx, window=window, causal=True)
        entry = {"k": k[:, S - window :], "v": v[:, S - window :]}
    else:
        if S <= 512:
            out = attn_lib.naive_attention(q, kx, vx, causal=True, window=window)
        else:
            out = attn_lib.flash_attention_xla(q, kx, vx, causal=True, window=window)
        entry = _pad_entry({"k": k, "v": v}, window if window else pad_to)
    attn_out = attn_lib._out_proj(lp["attn"], out, x.dtype)
    if cfg.parallel_block:
        return x + attn_out + _ffn(lp, cfg, shd, hin), entry
    x = x + attn_out
    return x + _ffn(lp, cfg, shd, apply_norm(cfg.norm, lp["norm2"], x)), entry


def lm_prefill(params, cfg: ArchConfig, shd: AxisRules, batch, pad_to=None):
    """Full forward building the cache. Returns (last-token logits (B,V), cache).

    pad_to: optional cache headroom — full-attention caches are padded to this
    many slots so decode can continue past the prompt length.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = embed_tokens(params, cfg, shd, tokens)
    cache: Dict[str, Any] = {"len": jnp.asarray(S, jnp.int32)}

    if cfg.encoder_decoder:
        enc = encode_audio(params, cfg, shd, batch["frames"])
        x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]

        def body(h, lp):
            hn = apply_norm(cfg.norm, lp["norm1"], h)
            q, k, v = attn_lib._project_qkv(lp["attn"], cfg, hn)
            q = shd.constrain(q, "batch", None, "heads", None)
            k = shd.constrain(k, "batch", None, "kv_heads", None)
            v = shd.constrain(v, "batch", None, "kv_heads", None)
            kx, vx = attn_lib.repeat_kv(k, cfg.n_rep), attn_lib.repeat_kv(v, cfg.n_rep)
            out = attn_lib.flash_attention_xla(q, kx, vx, causal=True) if S > 512 else attn_lib.naive_attention(q, kx, vx, causal=True)
            h = h + attn_lib._out_proj(lp["attn"], out, h.dtype)
            hx = apply_norm(cfg.norm, lp["norm_x"], h)
            qx, kxx, vxx = attn_lib._project_qkv(lp["xattn"], cfg, hx, kv_x=enc)
            kxe, vxe = attn_lib.repeat_kv(kxx, cfg.n_rep), attn_lib.repeat_kv(vxx, cfg.n_rep)
            outx = attn_lib.flash_attention_xla(qx, kxe, vxe, causal=False)
            h = h + attn_lib._out_proj(lp["xattn"], outx, h.dtype)
            h = h + apply_mlp(lp["mlp"], cfg, shd, apply_norm(cfg.norm, lp["norm2"], h))
            se = _pad_entry({"k": k, "v": v}, pad_to)
            return h, {"k": se["k"], "v": se["v"], "xk": kxx, "xv": vxx}

        x, ys = flags.scan(body, x, strip_params(params["dec_layers"]))
        cache["self"] = {"k": ys["k"], "v": ys["v"]}
        cache["cross_k"], cache["cross_v"] = ys["xk"], ys["xv"]
        logits = logits_fn(params, cfg, shd, x[:, -1:])
        return logits[:, 0], cache

    if cfg.is_hybrid:
        pat = cfg.block_pattern
        n_full = cfg.n_layers // len(pat)
        rem = cfg.n_layers % len(pat)
        groups = {}

        def gbody(h, lps):
            entries = []
            for j, kind in enumerate(pat):
                w = cfg.local_window if kind == "attn" else 0
                h, e = _attn_block_prefill(lps[j], cfg, shd, kind, h, positions, w, pad_to)
                entries.append(e)
            return h, tuple(entries)

        vals = tuple(strip_params(params["groups"][f"g{j}_{k}"]) for j, k in enumerate(pat))
        x, ys = flags.scan(gbody, x, vals)
        for j, kind in enumerate(pat):
            groups[f"g{j}_{kind}"] = ys[j]
        cache["groups"] = groups
        cache["tail"] = []
        for i in range(rem):
            lp = strip_params(params["tail"][i])
            w = cfg.local_window if pat[i] == "attn" else 0
            x, e = _attn_block_prefill(lp, cfg, shd, pat[i], x, positions, w, pad_to)
            cache["tail"].append(jax.tree.map(lambda a: a[None], e))
        logits = logits_fn(params, cfg, shd, x[:, -1:])
        return logits[:, 0], cache

    kind = cfg.layer_kinds()[0]

    def body(h, lp):
        return _attn_block_prefill(lp, cfg, shd, kind, h, positions, 0, pad_to)

    x, ys = flags.scan(body, x, strip_params(params["layers"]))
    cache["layers"] = ys
    logits = logits_fn(params, cfg, shd, x[:, -1:])
    return logits[:, 0], cache


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------


def _attn_block_step(lp, cfg: ArchConfig, shd, x, kc, vc, pos, positions3, *, ring):
    """x (B,1,D); kc/vc (B,S,KV,Dh). Returns (x', kc', vc')."""
    B = x.shape[0]
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    hin = apply_norm(cfg.norm, lp["norm"] if cfg.parallel_block else lp["norm1"], x)
    q, k, v = attn_lib._project_qkv(lp["attn"], cfg, hin)
    if cfg.mrope_sections is not None:
        pos_ids = positions3[:, :, None] if positions3 is not None else jnp.broadcast_to(
            pos, (B, 3, 1)
        )
        q, k = _rope(cfg, q, pos_ids), _rope(cfg, k, pos_ids)
    else:
        pos_ids = jnp.broadcast_to(pos[None, None], (B, 1))
        q, k = _rope(cfg, q, pos_ids), _rope(cfg, k, pos_ids)
    out, kc, vc = attn_lib.decode_attn_cached(
        cfg, shd, q[:, 0], k[:, 0], v[:, 0], kc, vc, pos, ring=ring
    )
    attn_out = attn_lib._out_proj(lp["attn"], out[:, None], x.dtype)
    if cfg.parallel_block:
        return x + attn_out + _ffn(lp, cfg, shd, hin), kc, vc
    x = x + attn_out
    return x + _ffn(lp, cfg, shd, apply_norm(cfg.norm, lp["norm2"], x)), kc, vc


def _block_step(lp, cfg: ArchConfig, shd, kind, x, cl, pos, positions3, *, ring):
    if kind == "ssm":
        h, st = apply_ssm_step(lp["ssm"], cfg, shd, apply_norm(cfg.norm, lp["norm"], x), cl)
        return x + h, st
    if kind == "rglru":
        h, st = apply_rglru_step(lp["rglru"], cfg, shd, apply_norm(cfg.norm, lp["norm1"], x), cl)
        x = x + h
        return x + apply_mlp(lp["mlp"], cfg, shd, apply_norm(cfg.norm, lp["norm2"], x)), st
    x, kc, vc = _attn_block_step(lp, cfg, shd, x, cl["k"], cl["v"], pos, positions3, ring=ring)
    return x, {"k": kc, "v": vc}


def lm_decode_step(params, cfg: ArchConfig, shd: AxisRules, cache, batch):
    """One-token decode. batch: {"token": (B,) int32 [, "positions": (B,3)]}.

    Returns (logits (B,V), new cache).
    """
    token = batch["token"]
    B = token.shape[0]
    pos = cache["len"]
    positions3 = batch.get("positions")
    x = embed_tokens(params, cfg, shd, token[:, None])

    if cfg.encoder_decoder:
        x = _encdec_pos(params, pos, x)

        def body(h, xs):
            lp, cl, xk, xv = xs
            hn = apply_norm(cfg.norm, lp["norm1"], h)
            q, k, v = attn_lib._project_qkv(lp["attn"], cfg, hn)
            out, kc, vc = attn_lib.decode_attn_cached(
                cfg, shd, q[:, 0], k[:, 0], v[:, 0], cl["k"], cl["v"], pos, ring=False
            )
            h = h + attn_lib._out_proj(lp["attn"], out[:, None], h.dtype)
            hx = apply_norm(cfg.norm, lp["norm_x"], h)
            qx = jnp.einsum("bsd,de->bse", hx, lp["xattn"]["wq"].astype(h.dtype))
            if "bq" in lp["xattn"]:
                qx = qx + lp["xattn"]["bq"].astype(h.dtype)
            qx = qx.reshape(B, 1, cfg.n_heads, cfg.head_dim)
            outx, _, _ = attn_lib.decode_attn_cached(
                cfg, shd, qx[:, 0], None, None, xk, xv, jnp.asarray(xk.shape[1], jnp.int32)
            )
            h = h + attn_lib._out_proj(lp["xattn"], outx[:, None], h.dtype)
            h = h + apply_mlp(lp["mlp"], cfg, shd, apply_norm(cfg.norm, lp["norm2"], h))
            return h, {"k": kc, "v": vc}

        x, new_self = flags.scan(
            body,
            x,
            (strip_params(params["dec_layers"]), cache["self"], cache["cross_k"], cache["cross_v"]),
        )
        new_cache = dict(cache)
        new_cache["self"] = new_self
        new_cache["len"] = pos + 1
        logits = logits_fn(params, cfg, shd, x)
        return logits[:, 0], new_cache

    if cfg.is_hybrid:
        pat = cfg.block_pattern
        rem = cfg.n_layers % len(pat)
        new_groups = {}
        gvals = {f"g{j}_{k}": strip_params(params["groups"][f"g{j}_{k}"]) for j, k in enumerate(pat)}

        def gbody(h, xs):
            new_entries = {}
            for j, kind in enumerate(pat):
                nm = f"g{j}_{kind}"
                h, st = _block_step(xs[nm + "_p"], cfg, shd, kind, h, xs[nm + "_c"], pos, positions3, ring=True)
                new_entries[nm] = st
            return h, new_entries

        xs = {}
        for j, kind in enumerate(pat):
            nm = f"g{j}_{kind}"
            xs[nm + "_p"] = gvals[nm]
            xs[nm + "_c"] = cache["groups"][nm]
        x, new_groups = flags.scan(gbody, x, xs)
        new_tail = []
        for i in range(rem):
            lp = strip_params(params["tail"][i])
            cl = jax.tree.map(lambda a: a[0], cache["tail"][i])
            x, st = _block_step(lp, cfg, shd, pat[i], x, cl, pos, positions3, ring=True)
            new_tail.append(jax.tree.map(lambda a: a[None], st))
        new_cache = dict(cache)
        new_cache["groups"] = new_groups
        new_cache["tail"] = new_tail
        new_cache["len"] = pos + 1
        logits = logits_fn(params, cfg, shd, x)
        return logits[:, 0], new_cache

    kind = cfg.layer_kinds()[0]

    def body(h, xs):
        lp, cl = xs
        h, st = _block_step(lp, cfg, shd, kind, h, cl, pos, positions3, ring=False)
        return h, st

    x, new_layers = flags.scan(body, x, (strip_params(params["layers"]), cache["layers"]))
    new_cache = dict(cache)
    new_cache["layers"] = new_layers
    new_cache["len"] = pos + 1
    logits = logits_fn(params, cfg, shd, x)
    return logits[:, 0], new_cache


def _encdec_pos(params, pos, x):
    """Sinusoidal decoder position embedding at a single (traced) position."""
    d = x.shape[-1]
    inv = 1.0 / (10_000 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / (d)))
    ang = pos.astype(jnp.float32) * inv
    p = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])
    return x + p.astype(x.dtype)[None, None]
