"""Sharded, resharding-aware checkpointing (no external deps).

Layout: <dir>/step_<N>/
  manifest.json            tree structure, shapes, dtypes, save-time mesh
  <leaf-path>.npy          one file per leaf (full array; per-shard files
                           would be the multi-host extension — the manifest
                           already records the save-time sharding so a
                           restore onto a DIFFERENT mesh just re-shards)

Restart semantics: save is atomic (write to tmp dir, rename); restore picks
the latest complete step.  Optimizer state and data-pipeline state ride
along, so a restart resumes the exact token stream (see data/pipeline.py).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


from jax.tree_util import keystr, tree_flatten_with_path


def _flatten(tree):
    """[(stable-path-string, leaf)] in treedef order."""
    kls, _ = tree_flatten_with_path(tree)
    return [(keystr(kp), leaf) for kp, leaf in kls]


def save_checkpoint(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    """Atomic checkpoint save; prunes to the newest `keep` steps."""
    flat = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    manifest = {}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(leaf)
        dtype_name = str(arr.dtype)
        if dtype_name not in ("float64", "float32", "float16", "int64", "int32",
                              "int16", "int8", "uint8", "uint16", "uint32", "uint64", "bool"):
            # ml_dtypes (bfloat16, fp8, ...) round-trip .npy as void — store
            # the raw bits as uint8 and record the logical dtype
            arr = arr.view(np.uint8)
        fn = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest[path] = {"file": fn, "shape": list(np.asarray(leaf).shape), "dtype": dtype_name}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # prune old steps
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir) if d.startswith("step_")
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))
    ]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, proto, *, step: Optional[int] = None, shardings=None):
    """Restore into the structure of `proto`; optionally device_put with the
    target mesh's shardings (resharding-aware restore: the save-time mesh is
    irrelevant because leaves are stored whole)."""
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    import ml_dtypes

    kls, treedef = tree_flatten_with_path(proto)
    leaves = []
    for kp, _ in kls:
        meta = manifest["leaves"][keystr(kp)]
        arr = np.load(os.path.join(d, meta["file"]))
        if str(arr.dtype) != meta["dtype"]:  # raw-bits storage (ml_dtypes)
            arr = arr.view(np.dtype(getattr(ml_dtypes, meta["dtype"]))).reshape(meta["shape"])
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(jnp.asarray(a), s), tree, shardings)
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return step, tree
