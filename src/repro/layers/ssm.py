"""Mamba-1 selective SSM block (falcon-mamba-7b).

Train/prefill uses a parallel associative scan over time; decode carries
(ssm state (B, d_inner, N), conv buffer (B, K-1, d_inner)).
`d_inner` channels shard over the `model` axis.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.sharding import dense_init, ones_init, zeros_init, Param, name_key


def init_ssm(key, cfg: ArchConfig, dtype=jnp.float32):
    D, di, N, R, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_dt_rank, cfg.ssm_conv
    # S4D-real initialization for A
    a_init = jnp.log(jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N)))
    k_dt = name_key(key, "dt_bias")
    dt_bias = jnp.log(jnp.exp(jnp.exp(
        jax.random.uniform(k_dt, (di,), jnp.float32) * (jnp.log(0.1) - jnp.log(0.001))
        + jnp.log(0.001)
    )) - 1.0 + 1e-9)  # inverse-softplus of dt in [1e-3, 1e-1]
    return {
        "in_proj": dense_init(key, "in_proj", (D, 2 * di), P(("embed", "fsdp"), "d_inner"), dtype),
        "conv_w": dense_init(key, "conv_w", (K, di), P(None, "d_inner"), dtype, scale=0.5),
        "conv_b": zeros_init("conv_b", (di,), P("d_inner"), dtype),
        "x_proj": dense_init(key, "x_proj", (di, R + 2 * N), P("d_inner", None), dtype),
        "dt_proj": dense_init(key, "dt_proj", (R, di), P(None, "d_inner"), dtype),
        "dt_bias": Param(dt_bias, P("d_inner")),
        "A_log": Param(a_init, P("d_inner", None)),
        "Dp": ones_init("Dp", (di,), P("d_inner"), jnp.float32),
        "out_proj": dense_init(key, "out_proj", (di, D), P("d_inner", ("embed", "fsdp")), dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x (B,S,di), w (K,di) -> (B,S,di)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K))
    return out + b


def _ssm_core(params, x_c, dt_r, B_ssm, C_ssm):
    """Selective scan. x_c (B,S,di), dt_r (B,S,R), B/C (B,S,N) -> (B,S,di)."""
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_r, params["dt_proj"].astype(dt_r.dtype)).astype(jnp.float32)
        + params["dt_bias"]
    )  # (B,S,di) fp32
    A = -jnp.exp(params["A_log"])  # (di,N)
    dA = jnp.exp(dt[..., None] * A)  # (B,S,di,N)
    dBx = (dt * x_c.astype(jnp.float32))[..., None] * B_ssm.astype(jnp.float32)[:, :, None, :]

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", h, C_ssm.astype(jnp.float32))
    y = (y + params["Dp"] * x_c.astype(jnp.float32)).astype(x_c.dtype)
    return y, h[:, -1]


def apply_ssm(params, cfg: ArchConfig, shd, x: jnp.ndarray, return_state: bool = False):
    """Full-sequence forward. x (B,S,D) -> (B,S,D) [, cache]."""
    di, N, R, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_dt_rank, cfg.ssm_conv
    dt = x.dtype
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt))
    xz = shd.constrain(xz, "batch", None, "d_inner")
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c = jax.nn.silu(_causal_conv(x_in, params["conv_w"].astype(dt), params["conv_b"].astype(dt)))
    xdb = jnp.einsum("bsd,de->bse", x_c, params["x_proj"].astype(dt))
    dt_r, B_ssm, C_ssm = jnp.split(xdb, [R, R + N], axis=-1)
    y, h_last = _ssm_core(params, x_c, dt_r, B_ssm, C_ssm)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"].astype(dt))
    if return_state:
        conv_tail = x_in[:, x.shape[1] - (K - 1) :]
        return out, {"h": h_last, "conv": conv_tail}
    return out


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    di, N, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {
        "h": jnp.zeros((batch, di, N), jnp.float32),
        "conv": jnp.zeros((batch, K - 1, di), dtype),
    }


def apply_ssm_step(params, cfg: ArchConfig, shd, x, cache) -> Tuple[jnp.ndarray, dict]:
    """Single decode step. x (B,1,D), cache {h, conv} -> (y (B,1,D), cache)."""
    di, N, R, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_dt_rank, cfg.ssm_conv
    dt_ = x.dtype
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt_))
    x_in, z = jnp.split(xz, 2, axis=-1)  # (B,1,di)
    window = jnp.concatenate([cache["conv"], x_in], axis=1)  # (B,K,di)
    w = params["conv_w"].astype(dt_)
    x_c = jax.nn.silu((window * w[None]).sum(1, keepdims=True) + params["conv_b"].astype(dt_))
    xdb = jnp.einsum("bsd,de->bse", x_c, params["x_proj"].astype(dt_))
    dt_r, B_ssm, C_ssm = jnp.split(xdb, [R, R + N], axis=-1)
    dtv = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_r, params["dt_proj"].astype(dt_r.dtype)).astype(jnp.float32)
        + params["dt_bias"]
    )[:, 0]  # (B,di)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dtv[..., None] * A)  # (B,di,N)
    dBx = (dtv * x_c[:, 0].astype(jnp.float32))[..., None] * B_ssm[:, 0].astype(jnp.float32)[:, None, :]
    h = cache["h"] * dA + dBx
    y = jnp.einsum("bdn,bn->bd", h, C_ssm[:, 0].astype(jnp.float32))
    y = (y + params["Dp"] * x_c[:, 0].astype(jnp.float32)).astype(dt_)[:, None]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"].astype(dt_))
    return out, {"h": h, "conv": window[:, 1:]}
