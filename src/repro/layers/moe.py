"""Mixture-of-Experts with expert parallelism.

Design (TPU-native, see DESIGN.md §6): expert weights are sharded over the
`model` mesh axis; token activations are replicated over `model` (they are
batch-sharded over `data`/`pod`).  Each expert shard *locally selects* the
token assignments routed to its experts (zero-communication dispatch), runs
its experts, scatters weighted outputs back to token positions, and a single
psum over `model` combines partial outputs — the same collective cost as one
tensor-parallel FFN all-reduce.  Capacity-factor dropping bounds buffers.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.layers.common import activation
from repro.sharding import AxisRules, dense_init

try:
    from jax import shard_map  # type: ignore
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore


def init_moe(key, cfg: ArchConfig, dtype=jnp.float32):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "wr": dense_init(key, "wr", (D, E), P("embed", None), jnp.float32),
        "wg": dense_init(key, "wg", (E, D, F), P("expert", "fsdp", None), dtype),
        "wu": dense_init(key, "wu", (E, D, F), P("expert", "fsdp", None), dtype),
        "wd": dense_init(key, "wd", (E, F, D), P("expert", "fsdp", None), dtype),
    }
    return p


def _capacity(cfg: ArchConfig, n_tokens: int, n_local_experts: int) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(c, 4)


def _expert_ffn(cfg: ArchConfig, wg, wu, wd, buf):
    """buf (E_l, C, D) -> (E_l, C, D)."""
    dt = buf.dtype
    g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(dt))
    h = activation("silu", g) * u if cfg.mlp_act == "swiglu" else activation(cfg.mlp_act, g)
    return jnp.einsum("ecf,efd->ecd", h, wd.astype(dt))


def _route(cfg: ArchConfig, wr, x_flat):
    """x_flat (T,D) -> gates (T,k) fp32, expert ids (T,k) int32."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), wr.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx.astype(jnp.int32)


def _moe_local(cfg: ArchConfig, params_local, x, e0: jnp.ndarray, n_local: int):
    """Per-shard MoE body. x (B_l, S, D); processes experts [e0, e0+n_local)."""
    B, S, D = x.shape
    T = B * S
    x_flat = x.reshape(T, D)
    gates, idx = _route(cfg, params_local["wr"], x_flat)
    k = cfg.top_k
    C = _capacity(cfg, T, n_local)

    eid = idx.reshape(T * k)
    w = gates.reshape(T * k).astype(x.dtype)
    tid = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)

    le = eid - e0
    local = (le >= 0) & (le < n_local)
    le_safe = jnp.clip(le, 0, n_local - 1)
    onehot = jax.nn.one_hot(jnp.where(local, le_safe, n_local), n_local + 1, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1  # running rank within each local expert
    pos_a = jnp.take_along_axis(pos, le_safe[:, None], axis=1)[:, 0]
    keep = local & (pos_a < C)
    dest = jnp.where(keep, le_safe * C + pos_a, n_local * C)  # overflow row

    buf = jnp.zeros((n_local * C + 1, D), x.dtype)
    buf = buf.at[dest].add(x_flat[tid] * keep.astype(x.dtype)[:, None])
    out = _expert_ffn(
        cfg,
        params_local["wg"],
        params_local["wu"],
        params_local["wd"],
        buf[: n_local * C].reshape(n_local, C, D),
    ).reshape(n_local * C, D)
    out = jnp.concatenate([out, jnp.zeros((1, D), out.dtype)], axis=0)

    contrib = out[dest] * (w * keep.astype(w.dtype))[:, None]
    y = jnp.zeros((T, D), x.dtype).at[tid].add(contrib)
    return y.reshape(B, S, D)


def apply_moe(params, cfg: ArchConfig, shd: AxisRules, x: jnp.ndarray) -> jnp.ndarray:
    """x (B,S,D) -> (B,S,D)."""
    if shd.mesh is None or "model" not in shd.axis_sizes or shd.axis_sizes["model"] == 1:
        return _moe_local(cfg, params, x, jnp.int32(0), cfg.n_experts)

    n_shards = shd.axis_sizes["model"]
    if cfg.n_experts % n_shards != 0:
        return _moe_local(cfg, params, x, jnp.int32(0), cfg.n_experts)
    n_local = cfg.n_experts // n_shards
    batch_spec = shd.resolve(P("batch"), (x.shape[0],))
    x_spec = P(batch_spec[0], None, None)
    # experts may be FSDP-sharded on the contraction dim; gather inside body
    fsdp_ax = shd.resolve(P("fsdp"), (cfg.d_model,))[0]
    w_spec = P("model", fsdp_ax, None)

    def body(wr, wg, wu, wd, x_l):
        m = jax.lax.axis_index("model")
        if fsdp_ax is not None:
            wg = jax.lax.all_gather(wg, fsdp_ax, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp_ax, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp_ax, axis=1, tiled=True)
        pl = {"wr": wr, "wg": wg, "wu": wu, "wd": wd}
        y = _moe_local(cfg, pl, x_l, m * n_local, n_local)
        return jax.lax.psum(y, "model")

    return shard_map(
        body,
        mesh=shd.mesh,
        in_specs=(P(), w_spec, w_spec, w_spec, x_spec),
        out_specs=x_spec,
    )(params["wr"], params["wg"], params["wu"], params["wd"], x)
