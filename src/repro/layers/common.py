"""Norms, activations, rotary embeddings (incl. partial-rotary and M-RoPE)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding import ones_init, zeros_init


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(kind: str, d: int, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": ones_init("scale", (d,), P("embed"), dtype)}
    if kind == "layernorm":
        return {
            "scale": ones_init("scale", (d,), P("embed"), dtype),
            "bias": zeros_init("bias", (d,), P("embed"), dtype),
        }
    if kind == "layernorm_nobias":
        return {"scale": ones_init("scale", (d,), P("embed"), dtype)}
    raise ValueError(kind)


def apply_norm(kind: str, params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    if kind == "rmsnorm":
        x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
        return (x * params["scale"].astype(jnp.float32)).astype(dt)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    x = x * params["scale"].astype(jnp.float32)
    if "bias" in params:
        x = x + params["bias"].astype(jnp.float32)
    return x.astype(dt)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def activation(name: str, x: jnp.ndarray) -> jnp.ndarray:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if name == "sq_relu":  # nemotron-4 squared ReLU
        r = jax.nn.relu(x)
        return r * r
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(name)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, rope_pct: float, theta: float) -> jnp.ndarray:
    """Inverse frequencies for the rotated slice of the head dim."""
    rot = int(head_dim * rope_pct)
    rot -= rot % 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(
    x: jnp.ndarray,  # (..., S, H, Dh)
    positions: jnp.ndarray,  # (..., S) int32
    rope_pct: float,
    theta: float,
) -> jnp.ndarray:
    Dh = x.shape[-1]
    inv = rope_freqs(Dh, rope_pct, theta)  # (rot/2,)
    rot = inv.shape[0] * 2
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (...,S,1,rot/2)
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


def apply_mrope(
    x: jnp.ndarray,  # (..., S, H, Dh)
    positions: jnp.ndarray,  # (..., 3, S) int32 — (temporal, h, w) per token
    sections: Tuple[int, int, int],  # head_dim/2 split across (t, h, w)
    theta: float,
) -> jnp.ndarray:
    """Qwen2-VL multimodal rotary: frequency bands split across 3 position ids."""
    Dh = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, Dh, 2, dtype=jnp.float32) / Dh))  # (Dh/2,)
    # section id per frequency band
    sec = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)]
    )  # (Dh/2,)
    # positions (..., 3, S) -> (..., S, Dh/2) by selecting the section per band
    p = jnp.moveaxis(positions, -2, -1).astype(jnp.float32)  # (..., S, 3)
    band_pos = jnp.take_along_axis(
        jnp.broadcast_to(p[..., None, :], p.shape[:-1] + (sec.shape[0], 3)),
        jnp.broadcast_to(sec[None, :, None], p.shape[:-1] + (sec.shape[0], 1)).astype(jnp.int32),
        axis=-1,
    )[..., 0]  # (..., S, Dh/2)
    ang = band_pos * inv  # (..., S, Dh/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : Dh // 2], x[..., Dh // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, d: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal embeddings (n_pos, d)."""
    inv = 1.0 / (10_000 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = jnp.arange(n_pos, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
