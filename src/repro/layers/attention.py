"""GQA attention: train/prefill (scan-flash, local-window, bidirectional),
and decode with a sequence-sharded KV cache (flash-decoding style lse-combine).

Three execution tiers:
  * naive O(S^2) reference           — tests / tiny shapes (`naive_attention`)
  * scan-flash (pure XLA, online softmax over KV chunks) — production CPU/XLA path
  * Pallas TPU kernel (kernels/flash_attention.py)        — TPU target, opt-in
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.sharding import AxisRules, dense_init, zeros_init

try:  # jax>=0.6 moved shard_map to jax.shard_map
    from jax import shard_map  # type: ignore
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_attn(key, cfg: ArchConfig, dtype=jnp.float32, cross: bool = False):
    D, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(key, "wq", (D, H * Dh), P("embed", "heads"), dtype),
        "wk": dense_init(key, "wk", (D, KV * Dh), P("embed", "kv_heads"), dtype),
        "wv": dense_init(key, "wv", (D, KV * Dh), P("embed", "kv_heads"), dtype),
        "wo": dense_init(key, "wo", (H * Dh, D), P("heads", "embed"), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros_init("bq", (H * Dh,), P("heads"), dtype)
        p["bk"] = zeros_init("bk", (KV * Dh,), P("kv_heads"), dtype)
        p["bv"] = zeros_init("bv", (KV * Dh,), P("kv_heads"), dtype)
    if cfg.mlp_bias:
        p["bo"] = zeros_init("bo", (D,), P("embed"), dtype)
    return p


def _project_qkv(params, cfg: ArchConfig, x, kv_x=None):
    """x (B,S,D) -> q (B,S,H,Dh), k/v (B,S_kv,KV,Dh)."""
    B, S, D = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,de->bse", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", kv_x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", kv_x, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, kv_x.shape[1], KV, Dh)
    v = v.reshape(B, kv_x.shape[1], KV, Dh)
    return q, k, v


def _out_proj(params, x_attn, dtype):
    """(B,S,H,Dh) -> (B,S,D)."""
    B, S, H, Dh = x_attn.shape
    out = jnp.einsum("bse,ed->bsd", x_attn.reshape(B, S, H * Dh), params["wo"].astype(dtype))
    if "bo" in params:
        out = out + params["bo"].astype(dtype)
    return out


def repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B,S,KV,Dh) -> (B,S,KV*n_rep,Dh)."""
    if n_rep == 1:
        return k
    B, S, KV, Dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, KV, n_rep, Dh)).reshape(
        B, S, KV * n_rep, Dh
    )


# ---------------------------------------------------------------------------
# Naive reference (tests / tiny)
# ---------------------------------------------------------------------------


def naive_attention(q, k, v, causal: bool, window: int = 0, q_offset: int = 0):
    """q (B,Sq,H,Dh), k/v (B,Sk,H,Dh) -> (B,Sq,H,Dh). fp32 softmax."""
    Dh = q.shape[-1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / jnp.sqrt(Dh)
    Sq, Sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


# ---------------------------------------------------------------------------
# Scan-flash (online softmax over KV chunks) — pure XLA production path
# ---------------------------------------------------------------------------


def flash_attention_xla(
    q, k, v, *, causal: bool, window: int = 0, chunk: int = 1024, q_offset: int = 0
):
    """Memory-bounded attention: scan over KV chunks with online softmax.

    q (B,Sq,H,Dh), k/v (B,Sk,H,Dh) with H already GQA-expanded.
    """
    B, Sq, H, Dh = q.shape
    Sk = k.shape[1]
    chunk = min(chunk, Sk)
    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, H, Dh).transpose(1, 0, 3, 2, 4)  # (n,B,H,C,Dh)
    vc = v.reshape(B, n_chunks, chunk, H, Dh).transpose(1, 0, 3, 2, 4)
    qT = q.transpose(0, 2, 1, 3)  # (B,H,Sq,Dh)
    scale = 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    qpos = jnp.arange(Sq) + q_offset

    def step(carry, inp):
        m, l, acc = carry
        j, k_j, v_j = inp
        s = jnp.einsum("bhqd,bhcd->bhqc", qT, k_j).astype(jnp.float32) * scale
        kpos = j * chunk + jnp.arange(chunk)
        valid = kpos[None, :] < Sk
        if causal:
            valid &= kpos[None, :] <= qpos[:, None]
        if window:
            valid &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(valid[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqc,bhcd->bhqd", p.astype(v_j.dtype), v_j
        ).astype(jnp.float32)
        return (m_new, l, acc), None

    init = (
        jnp.full((B, H, Sq), -1e30, jnp.float32),
        jnp.zeros((B, H, Sq), jnp.float32),
        jnp.zeros((B, H, Sq, Dh), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(step, init, (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,Sq,H,Dh)


# ---------------------------------------------------------------------------
# Local (sliding-window) attention via chunking — exact for window <= chunk
# ---------------------------------------------------------------------------


def local_attention_xla(q, k, v, *, window: int, causal: bool = True):
    """Chunked sliding-window attention. q/k/v (B,S,H,Dh), H pre-expanded.

    Each query chunk of size W attends to [its own chunk, previous chunk],
    masked to the exact window — O(S * 2W) memory/compute.
    """
    B, S, H, Dh = q.shape
    W = window
    if S <= W:
        return naive_attention(q, k, v, causal=causal, window=W)
    n = -(-S // W)
    pad = n * W - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qc = q.reshape(B, n, W, H, Dh)
    kc = k.reshape(B, n, W, H, Dh)
    vc = v.reshape(B, n, W, H, Dh)
    k_prev = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kc], axis=2)  # (B,n,2W,H,Dh)
    v2 = jnp.concatenate([v_prev, vc], axis=2)
    s = jnp.einsum("bnqhd,bnkhd->bnhqk", qc, k2).astype(jnp.float32) / jnp.sqrt(Dh)
    qpos = jnp.arange(W)[:, None] + W  # position within [prev, cur] frame
    kpos = jnp.arange(2 * W)[None, :]
    mask = (kpos <= qpos) if causal else jnp.ones((W, 2 * W), bool)
    mask &= kpos > qpos - W
    # first chunk has no previous chunk
    first = jnp.arange(n)[:, None, None] > 0
    mask_n = mask[None] & (first | (kpos[None] >= W))
    s = jnp.where(mask_n[None, :, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnhqk,bnkhd->bnqhd", p.astype(v2.dtype), v2)
    out = out.reshape(B, n * W, H, Dh)
    return out[:, :S]


# ---------------------------------------------------------------------------
# Decode attention with sequence-sharded KV cache (flash-decoding)
# ---------------------------------------------------------------------------


def _gqa_partials(q, k_cache, v_cache, valid):
    """GQA partial attention without head expansion.

    q (B,KV,rep,Dh); k/v_cache (B,C,KV,Dh); valid (C,) bool.
    Returns fp32 (num (B,KV,rep,Dh), den (B,KV,rep), m (B,KV,rep)).
    """
    Dh = q.shape[-1]
    s = jnp.einsum("bkrd,bckd->bkrc", q, k_cache).astype(jnp.float32) / jnp.sqrt(Dh)
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    den = p.sum(-1)
    num = jnp.einsum("bkrc,bckd->bkrd", p.astype(v_cache.dtype), v_cache).astype(jnp.float32)
    return num, den, m


def decode_attn_cached(
    cfg: ArchConfig,
    shd: AxisRules,
    q,  # (B, H, Dh) — rope already applied
    k_new,  # (B, KV, Dh) or None (cross-attention / no write)
    v_new,
    k_cache,  # (B, S, KV, Dh)
    v_cache,
    cache_len,  # scalar int32: #valid entries BEFORE this step
    *,
    ring: bool = False,  # ring buffer (sliding-window) cache
):
    """One-token attention against a (possibly sequence-sharded) KV cache.

    Writes (k_new, v_new) at cache_len (mod S for ring), attends over valid
    entries, lse-combining partials across the `model` axis when the cache's
    sequence dim is sharded (flash-decoding).  Returns (out (B,H,Dh), k_cache,
    v_cache).
    """
    B, S, KV, Dh = k_cache.shape
    H = q.shape[1]
    rep = H // KV
    qg = q.reshape(B, KV, rep, Dh)

    kv_axes = shd.resolve(P("kv_seq"), (S,)) if shd.mesh is not None else P(None)
    sharded = kv_axes[0] is not None

    def write(kc, vc, kn, vn, slot, mine):
        upd_k = jax.lax.dynamic_update_slice(kc, kn[:, None], (0, slot, 0, 0))
        upd_v = jax.lax.dynamic_update_slice(vc, vn[:, None], (0, slot, 0, 0))
        kc = jnp.where(mine, upd_k, kc)
        vc = jnp.where(mine, upd_v, vc)
        return kc, vc

    if not sharded:
        if k_new is not None:
            slot = jnp.mod(cache_len, S) if ring else jnp.clip(cache_len, 0, S - 1)
            k_cache, v_cache = write(k_cache, v_cache, k_new, v_new, slot, True)
        n_valid = cache_len + (0 if k_new is None else 1)
        if ring:
            valid = jnp.arange(S) < jnp.minimum(n_valid, S)
        else:
            valid = jnp.arange(S) < n_valid
        num, den, m = _gqa_partials(qg, k_cache, v_cache, valid)
        out = num / jnp.maximum(den, 1e-30)[..., None]
        return out.reshape(B, H, Dh).astype(q.dtype), k_cache, v_cache

    # --- sequence-sharded cache: shard_map over the model axis -------------
    batch_ax = shd.resolve(P("batch"), (B,))[0]
    cache_spec = P(batch_ax, kv_axes[0], None, None)
    rep_spec_q = P(batch_ax, None, None)
    mesh_axis = kv_axes[0] if isinstance(kv_axes[0], str) else kv_axes[0][0]

    def body(qg_l, kn, vn, kc, vc, clen):
        s_local = kc.shape[1]
        idx = jax.lax.axis_index(mesh_axis)
        off = idx * s_local
        if kn is not None:
            tgt = (jnp.mod(clen, S) if ring else clen) - off
            mine = (tgt >= 0) & (tgt < s_local)
            slot = jnp.clip(tgt, 0, s_local - 1)
            kc, vc = write(kc, vc, kn, vn, slot, mine)
        n_valid = clen + (0 if kn is None else 1)
        pos = jnp.arange(s_local) + off
        if ring:
            valid = pos < jnp.minimum(n_valid, S)
        else:
            valid = pos < n_valid
        num, den, m = _gqa_partials(qg_l, kc, vc, valid)
        g_m = jax.lax.pmax(m, mesh_axis)
        corr = jnp.exp(m - g_m)
        num = jax.lax.psum(num * corr[..., None], mesh_axis)
        den = jax.lax.psum(den * corr, mesh_axis)
        out = num / jnp.maximum(den, 1e-30)[..., None]
        return out, kc, vc

    has_new = k_new is not None
    in_specs = (
        P(batch_ax, None, None, None),  # qg
        rep_spec_q if has_new else None,
        rep_spec_q if has_new else None,
        cache_spec,
        cache_spec,
        P(),
    )
    out_specs = (P(batch_ax, None, None, None), cache_spec, cache_spec)
    if not has_new:
        def body2(qg_l, kc, vc, clen):
            return body(qg_l, None, None, kc, vc, clen)

        out, k_cache, v_cache = shard_map(
            body2,
            mesh=shd.mesh,
            in_specs=(P(batch_ax, None, None, None), cache_spec, cache_spec, P()),
            out_specs=out_specs,
        )(qg, k_cache, v_cache, cache_len)
    else:
        out, k_cache, v_cache = shard_map(
            body,
            mesh=shd.mesh,
            in_specs=in_specs,
            out_specs=out_specs,
        )(qg, k_new, v_new, k_cache, v_cache, cache_len)
    return out.reshape(B, H, Dh).astype(q.dtype), k_cache, v_cache


def decode_attention_local(q, k_cache, v_cache, cache_len, *, pos_offset=0):
    """Partial attention over a local cache chunk; returns (num, denom, max).

    q (B,H,Dh); k/v_cache (B,C,H,Dh) — H pre-expanded.  Entries at global
    position >= cache_len are masked.  Returns fp32 partials for lse-combine.
    """
    Dh = q.shape[-1]
    s = jnp.einsum("bhd,bchd->bhc", q, k_cache).astype(jnp.float32) / jnp.sqrt(Dh)
    pos = jnp.arange(k_cache.shape[1]) + pos_offset
    s = jnp.where((pos < cache_len)[None, None, :], s, -1e30)
    m = s.max(-1)  # (B,H)
    p = jnp.exp(s - m[..., None])
    den = p.sum(-1)
    num = jnp.einsum("bhc,bchd->bhd", p.astype(v_cache.dtype), v_cache).astype(jnp.float32)
    return num, den, m


def combine_partials(num, den, m, axis_name: Optional[str]):
    """lse-weighted combine of partial attention across a mesh axis."""
    if axis_name is None:
        return num / jnp.maximum(den, 1e-30)[..., None]
    g_m = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - g_m)
    num = jax.lax.psum(num * corr[..., None], axis_name)
    den = jax.lax.psum(den * corr, axis_name)
    return num / jnp.maximum(den, 1e-30)[..., None]
