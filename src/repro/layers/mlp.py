"""Feed-forward blocks: SwiGLU (3-matrix) and 2-matrix (sq_relu / gelu)."""
from __future__ import annotations

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.layers.common import activation
from repro.sharding import dense_init, zeros_init


def init_mlp(key, cfg: ArchConfig, dtype=jnp.float32):
    D, F = cfg.d_model, cfg.d_ff
    if cfg.mlp_act in ("swiglu", "geglu"):
        p = {
            "wg": dense_init(key, "wg", (D, F), P(("embed", "fsdp"), "ff"), dtype),
            "wu": dense_init(key, "wu", (D, F), P(("embed", "fsdp"), "ff"), dtype),
            "wd": dense_init(key, "wd", (F, D), P("ff", ("embed", "fsdp")), dtype),
        }
    else:
        p = {
            "wu": dense_init(key, "wu", (D, F), P(("embed", "fsdp"), "ff"), dtype),
            "wd": dense_init(key, "wd", (F, D), P("ff", ("embed", "fsdp")), dtype),
        }
    if cfg.mlp_bias:
        p["bu"] = zeros_init("bu", (F,), P("ff"), dtype)
        p["bd"] = zeros_init("bd", (D,), P("embed"), dtype)
    return p


def apply_mlp(params, cfg: ArchConfig, shd, x: jnp.ndarray) -> jnp.ndarray:
    dt = x.dtype
    if cfg.mlp_act in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, params["wg"].astype(dt))
        u = jnp.einsum("bsd,df->bsf", x, params["wu"].astype(dt))
        h = activation("silu" if cfg.mlp_act == "swiglu" else "gelu", g) * u
    else:
        u = jnp.einsum("bsd,df->bsf", x, params["wu"].astype(dt))
        if "bu" in params:
            u = u + params["bu"].astype(dt)
        h = activation(cfg.mlp_act, u)
    h = shd.constrain(h, "batch", None, "ff")
    out = jnp.einsum("bsf,fd->bsd", h, params["wd"].astype(dt))
    if "bd" in params:
        out = out + params["bd"].astype(dt)
    return out
