"""RG-LRU recurrent block (recurrentgemma / Griffin).

Block: two branches from x — (linear -> causal conv -> RG-LRU) gated by
(linear -> GeLU) — merged multiplicatively, then output projection.
Gates are per-channel (diagonal), per the Griffin formulation; recurrence
h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t) runs as an associative
scan over time.  `rnn` width channels shard over the `model` axis.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.sharding import Param, dense_init, zeros_init, name_key

_C = 8.0  # Griffin's fixed recurrence sharpness constant


def init_rglru(key, cfg: ArchConfig, dtype=jnp.float32):
    D, W, K = cfg.d_model, cfg.rnn_width, cfg.ssm_conv
    # Lambda init so that a = sigmoid(L)^c is in ~[0.9, 0.999]
    k = name_key(key, "lam")
    u = jax.random.uniform(k, (W,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (1.0 / _C) / (1.0 - u ** (1.0 / _C)))
    return {
        "w_in": dense_init(key, "w_in", (D, W), P("embed", "rnn"), dtype),
        "w_gate": dense_init(key, "w_gate", (D, W), P("embed", "rnn"), dtype),
        "conv_w": dense_init(key, "conv_w", (K, W), P(None, "rnn"), dtype, scale=0.5),
        "conv_b": zeros_init("conv_b", (W,), P("rnn"), dtype),
        "wa": zeros_init("wa", (W,), P("rnn"), jnp.float32),  # diagonal gate weights
        "ba": zeros_init("ba", (W,), P("rnn"), jnp.float32),
        "wx": zeros_init("wx", (W,), P("rnn"), jnp.float32),
        "bx": zeros_init("bx", (W,), P("rnn"), jnp.float32),
        "lam": Param(lam, P("rnn")),
        "w_out": dense_init(key, "w_out", (W, D), P("rnn", "embed"), dtype),
    }


def _conv(x, w, b):
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K)) + b


def _gates(params, xc32):
    """xc32 (..., W) fp32 -> (a, gated_input) per RG-LRU."""
    r = jax.nn.sigmoid(xc32 * params["wa"] + params["ba"])
    i = jax.nn.sigmoid(xc32 * params["wx"] + params["bx"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xc32)
    return a, b


def apply_rglru(params, cfg: ArchConfig, shd, x: jnp.ndarray, return_state: bool = False):
    """x (B,S,D) -> (B,S,D) [, cache]."""
    dt = x.dtype
    K = cfg.ssm_conv
    xi = jnp.einsum("bsd,dw->bsw", x, params["w_in"].astype(dt))
    xi = shd.constrain(xi, "batch", None, "rnn")
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["w_gate"].astype(dt)))
    xc = _conv(xi, params["conv_w"].astype(dt), params["conv_b"].astype(dt))
    a, b = _gates(params, xc.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h.astype(dt) * gate)
    out = jnp.einsum("bsw,wd->bsd", y, params["w_out"].astype(dt))
    if return_state:
        return out, {"h": h[:, -1], "conv": xi[:, x.shape[1] - (K - 1) :]}
    return out


def init_rglru_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    W, K = cfg.rnn_width, cfg.ssm_conv
    return {
        "h": jnp.zeros((batch, W), jnp.float32),
        "conv": jnp.zeros((batch, K - 1, W), dtype),
    }


def apply_rglru_step(params, cfg: ArchConfig, shd, x, cache) -> Tuple[jnp.ndarray, dict]:
    """x (B,1,D) -> (y (B,1,D), cache)."""
    dt = x.dtype
    xi = jnp.einsum("bsd,dw->bsw", x, params["w_in"].astype(dt))
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["w_gate"].astype(dt)))
    window = jnp.concatenate([cache["conv"], xi], axis=1)  # (B,K,W)
    w = params["conv_w"].astype(dt)
    xc = (window * w[None]).sum(1) + params["conv_b"].astype(dt)  # (B,W)
    a, b = _gates(params, xc.astype(jnp.float32))
    h = cache["h"] * a + b
    y = (h.astype(dt)[:, None] * gate)
    out = jnp.einsum("bsw,wd->bsd", y, params["w_out"].astype(dt))
    return out, {"h": h, "conv": window[:, 1:]}
