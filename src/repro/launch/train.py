"""Training launcher: real end-to-end training on the local device(s).

Example (quickstart-scale):
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
      --reduced --steps 100 --ckpt /tmp/ckpt

On a real TPU pod the same entry point runs with --mesh 16,16 (the mesh
axes come from launch/mesh.py; shardings resolve per-arch exactly as in
the dry-run).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.data.pipeline import make_pipeline
from repro.ft.runner import TrainRunner
from repro.models.lm import init_lm
from repro.sharding import AxisRules, unzip_params
from repro.train.steps import build_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", help="CPU-scale config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None, help="inject a failure (ft demo)")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)[0]
    shd = AxisRules(None)
    print(f"[train] arch={cfg.name} params={cfg.param_count():,} reduced={args.reduced}")

    train_step, optimizer = build_train_step(cfg, shd)
    jitted = jax.jit(train_step, donate_argnums=(0, 1))

    def init_state():
        params = unzip_params(init_lm(jax.random.PRNGKey(0), cfg, jnp.float32))[0]
        return params, optimizer.init(params)

    init_data, next_batch = make_pipeline(cfg.vocab_size, args.batch, args.seq)

    def batch_fn(ds):
        ds, b = next_batch(ds)
        if cfg.encoder_decoder:
            key = jax.random.fold_in(jax.random.PRNGKey(7), ds.step)
            b["frames"] = jax.random.normal(key, (args.batch, cfg.enc_seq_len, cfg.d_model))
        if cfg.mrope_sections is not None:
            b["positions"] = jnp.broadcast_to(
                jnp.arange(args.seq)[None, None], (args.batch, 3, args.seq)
            ).astype(jnp.int32)
        return ds, b

    runner = TrainRunner(
        jitted, init_state, batch_fn, init_data,
        ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every, fail_at=args.fail_at,
    )
    out = runner.run(args.steps)
    losses = out["losses"]
    print(f"[train] done: step={out['final_step']} first_loss={losses[0]:.4f} last_loss={losses[-1]:.4f}")
    if len(losses) > 20:
        assert losses[-1] < losses[0], "loss did not improve"
        print("[train] loss improved ✓")


if __name__ == "__main__":
    main()
