# The dry-run (and ONLY the dry-run) needs 512 placeholder devices so the
# production mesh can be built.  Must run before ANY other import — jax locks
# the device count on first init.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, cell_supported, get_config
from repro.configs.base import ShapeSpec
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    cache_structs,
    decode_batch_specs,
    input_specs,
    param_structs,
)
from repro.optim.optimizers import opt_state_specs
from repro.sharding import AxisRules
from repro.train.steps import build_decode_step, build_prefill, build_train_step

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+\[[^\]]*\][^ ]*)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(",
)

# The CPU backend upcasts bf16 compute to f32, so f32 collective bytes in
# these dry-runs are LOGICALLY bf16 on the TPU target; the roofline halves
# them (tracked separately here).
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Any]:
    """Per-device bytes moved by collectives (result-shape accounting)."""
    by_kind: Dict[str, int] = {}
    counts: Dict[str, int] = {}
    f32_bytes = 0
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        by_kind[kind] = by_kind.get(kind, 0) + b
        counts[kind] = counts.get(kind, 0) + 1
        # f32 shapes minus any non-f32 components
        f32_only = sum(
            int(np_prod(dims)) * 4
            for dt, dims in _SHAPE_RE.findall(shape_str)
            if dt == "f32"
        )
        f32_bytes += f32_only
    return {
        "bytes_by_kind": by_kind,
        "counts": counts,
        "total_bytes": sum(by_kind.values()),
        "f32_bytes": f32_bytes,
    }


def np_prod(dims_str: str) -> int:
    n = 1
    if dims_str:
        for d in dims_str.split(","):
            if d:
                n *= int(d)
    return n


def _cost(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items() if isinstance(v, (int, float))}
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def _memory(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return {}
        out = {}
        for attr in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ):
            if hasattr(ma, attr):
                out[attr] = int(getattr(ma, attr))
        return out
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


# sequence-parallel prefill rules (EXPERIMENTS.md §Perf iteration 4):
# weights replicate over `model`; the sequence dim shards instead.
SEQ_PAR_RULES = {
    "seq": ("model",),
    "heads": None,
    "kv_heads": None,
    "ff": None,
    "fsdp": ("data",),
}


def rules_for(cfg, shape, overrides):
    if shape.kind == "prefill" and cfg.seq_parallel_prefill:
        return {**overrides, **SEQ_PAR_RULES}
    if shape.kind in ("prefill", "decode") and not cfg.serve_fsdp:
        # iteration 6: no FSDP at serve time (kills per-step weight gathers)
        return {**overrides, "fsdp": None}
    return overrides


def lower_cell(arch_id: str, shape_name: str, multi_pod: bool):
    """Build + lower + compile one (arch x shape x mesh) cell."""
    cfg, overrides = get_config(arch_id)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    shd = AxisRules(mesh, rules_for(cfg, shape, overrides))
    rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    with mesh:
        p_shapes, p_specs, p_shards = param_structs(cfg, shd)
        if shape.kind == "train":
            train_step, optimizer = build_train_step(cfg, shd)
            opt_shapes = jax.eval_shape(optimizer.init, p_shapes)
            o_specs = opt_state_specs(cfg.optimizer, p_specs)
            o_shards = shd.resolve_tree(opt_shapes, o_specs)
            batch, b_shards = input_specs(cfg, shape, shd)
            step_struct = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(
                train_step,
                in_shardings=(p_shards, o_shards, rep, b_shards),
                out_shardings=(p_shards, o_shards, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(p_shapes, opt_shapes, step_struct, batch)
        elif shape.kind == "prefill":
            prefill = build_prefill(cfg, shd)
            batch, b_shards = input_specs(cfg, shape, shd)
            c_shapes, c_specs, c_shards = cache_structs(cfg, shape, shd)
            jitted = jax.jit(
                prefill,
                in_shardings=(p_shards, b_shards),
                out_shardings=(None, c_shards),
            )
            lowered = jitted.lower(p_shapes, batch)
        else:  # decode
            decode = build_decode_step(cfg, shd)
            batch, b_shards = decode_batch_specs(cfg, shape, shd)
            c_shapes, c_specs, c_shards = cache_structs(cfg, shape, shd)
            jitted = jax.jit(
                decode,
                in_shardings=(p_shards, c_shards, b_shards),
                out_shardings=(None, c_shards),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(p_shapes, c_shapes, batch)
        compiled = lowered.compile()
    return lowered, compiled, mesh


def run_cell(arch_id: str, shape_name: str, multi_pod: bool) -> Dict[str, Any]:
    cfg, _ = get_config(arch_id)
    shape = SHAPES[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }
    ok, why = cell_supported(cfg, shape)
    if not ok:
        rec.update(status="skip", reason=why)
        return rec
    t0 = time.time()
    try:
        lowered, compiled, mesh = lower_cell(arch_id, shape_name, multi_pod)
        hlo = compiled.as_text()
        rec.update(
            status="ok",
            compile_s=round(time.time() - t0, 1),
            cost=_cost(compiled),
            memory=_memory(compiled),
            collectives=collective_stats(hlo),
            n_devices=mesh.devices.size,
        )
        mem = rec["memory"]
        if mem and "error" not in mem:
            per_dev = sum(
                mem.get(k, 0)
                for k in ("argument_size_in_bytes", "output_size_in_bytes", "temp_size_in_bytes")
            ) - mem.get("alias_size_in_bytes", 0)
            rec["per_device_bytes_est"] = int(per_dev)
    except Exception as e:
        rec.update(
            status="error",
            compile_s=round(time.time() - t0, 1),
            error=f"{type(e).__name__}: {e}",
            traceback=traceback.format_exc()[-4000:],
        )
    return rec


# ---------------------------------------------------------------------------
# Calibration: scan bodies are counted ONCE by XLA cost_analysis (verified
# empirically), so full-size scanned lowerings under-count flops/bytes/
# collectives.  We lower small fully-UNROLLED variants at 2 (3 for enc-dec)
# layer counts and extrapolate linearly — exact for homogeneous stacks.
# ---------------------------------------------------------------------------
def _variant(cfg, **kw):
    return dataclasses.replace(cfg, **kw)


def _calib_points(cfg):
    """[(label, cfg-variant, n_units)] with unit = layer (or group/enc-dec)."""
    if cfg.is_hybrid:
        plen = len(cfg.block_pattern)
        rem = cfg.n_layers % plen
        return (
            [("g1", _variant(cfg, n_layers=plen + rem, microbatch=1), 1),
             ("g2", _variant(cfg, n_layers=2 * plen + rem, microbatch=1), 2)],
            cfg.n_layers // plen,
        )
    if cfg.encoder_decoder:
        return (
            [("e2d2", _variant(cfg, n_enc_layers=2, n_layers=2, microbatch=1), (2, 2)),
             ("e4d2", _variant(cfg, n_enc_layers=4, n_layers=2, microbatch=1), (4, 2)),
             ("e4d4", _variant(cfg, n_enc_layers=4, n_layers=4, microbatch=1), (4, 4))],
            (cfg.n_enc_layers, cfg.n_layers),
        )
    return (
        [("l2", _variant(cfg, n_layers=2, microbatch=1), 2),
         ("l4", _variant(cfg, n_layers=4, microbatch=1), 4)],
        cfg.n_layers,
    )


def _micro_shape(cfg, shape):
    """Train cells calibrate one microbatch's work (microbatch=1 variant)."""
    if shape.kind != "train" or cfg.microbatch == 1:
        return shape
    return ShapeSpec(shape.name, shape.seq_len, shape.global_batch // cfg.microbatch, shape.kind)


def _lower_variant(cfg_v, shape, overrides):
    from repro import flags
    from repro.configs import get_config

    mesh = make_production_mesh(multi_pod=False)
    shd = AxisRules(mesh, rules_for(cfg_v, shape, overrides))
    rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    flags.UNROLL_SCANS = True
    try:
        with mesh:
            p_shapes, p_specs, p_shards = param_structs(cfg_v, shd)
            if shape.kind == "train":
                train_step, optimizer = build_train_step(cfg_v, shd)
                opt_shapes = jax.eval_shape(optimizer.init, p_shapes)
                o_specs = opt_state_specs(cfg_v.optimizer, p_specs)
                o_shards = shd.resolve_tree(opt_shapes, o_specs)
                batch, b_shards = input_specs(cfg_v, shape, shd)
                jitted = jax.jit(
                    train_step,
                    in_shardings=(p_shards, o_shards, rep, b_shards),
                    out_shardings=(p_shards, o_shards, None),
                    donate_argnums=(0, 1),
                )
                lowered = jitted.lower(p_shapes, opt_shapes, jax.ShapeDtypeStruct((), jnp.int32), batch)
            elif shape.kind == "prefill":
                prefill = build_prefill(cfg_v, shd)
                batch, b_shards = input_specs(cfg_v, shape, shd)
                c_shapes, c_specs, c_shards = cache_structs(cfg_v, shape, shd)
                jitted = jax.jit(prefill, in_shardings=(p_shards, b_shards), out_shardings=(None, c_shards))
                lowered = jitted.lower(p_shapes, batch)
            else:
                decode = build_decode_step(cfg_v, shd)
                batch, b_shards = decode_batch_specs(cfg_v, shape, shd)
                c_shapes, c_specs, c_shards = cache_structs(cfg_v, shape, shd)
                jitted = jax.jit(
                    decode,
                    in_shardings=(p_shards, c_shards, b_shards),
                    out_shardings=(None, c_shards),
                    donate_argnums=(1,),
                )
                lowered = jitted.lower(p_shapes, c_shapes, batch)
            compiled = lowered.compile()
    finally:
        flags.UNROLL_SCANS = False
    cost = _cost(compiled)
    coll = collective_stats(compiled.as_text())
    return {
        "flops": cost.get("flops", 0.0),
        "bytes": cost.get("bytes accessed", 0.0),
        "coll": float(coll["total_bytes"]),
        "coll_f32": float(coll.get("f32_bytes", 0)),
    }


def per_device_param_bytes(cfg, overrides):
    """Exact per-device parameter bytes under the resolved shardings."""
    mesh = make_production_mesh(multi_pod=False)
    shd = AxisRules(mesh, overrides)
    p_shapes, p_specs, p_shards = param_structs(cfg, shd)
    total = [0]

    def acc(sh, sd):
        shard_shape = sd.shard_shape(tuple(sh.shape))
        n = 1
        for d in shard_shape:
            n *= d
        total[0] += n * sh.dtype.itemsize

    jax.tree.map(acc, p_shapes, p_shards)
    return total[0]


def calibrate_cell(arch_id: str, shape_name: str) -> Dict[str, Any]:
    cfg, overrides = get_config(arch_id)
    shape = SHAPES[shape_name]
    rec: Dict[str, Any] = {"arch": arch_id, "shape": shape_name, "mode": "calib"}
    ok, why = cell_supported(cfg, shape)
    if not ok:
        rec.update(status="skip", reason=why)
        return rec
    t0 = time.time()
    try:
        mshape = _micro_shape(cfg, shape)
        points, full_units = _calib_points(cfg)
        res = [( _lower_variant(cv, mshape, overrides), units) for _, cv, units in points]
        terms = {}
        for key in ("flops", "bytes", "coll", "coll_f32"):
            if cfg.encoder_decoder:
                (r1, u1), (r2, u2), (r3, u3) = res
                e_rate = (r2[key] - r1[key]) / (u2[0] - u1[0])
                d_rate = (r3[key] - r2[key]) / (u3[1] - u2[1])
                base = r1[key] - e_rate * u1[0] - d_rate * u1[1]
                full = base + e_rate * full_units[0] + d_rate * full_units[1]
            else:
                (r1, u1), (r2, u2) = res
                rate = (r2[key] - r1[key]) / (u2 - u1)
                base = r1[key] - rate * u1
                full = base + rate * full_units
            terms[key] = {"per_unit": rate if not cfg.encoder_decoder else (e_rate, d_rate),
                          "base": base, "full_micro": full}
        # train: one step = n_micro x micro-work + optimizer update once.
        n_micro = cfg.microbatch if shape.kind == "train" else 1
        pd_param_bytes = per_device_param_bytes(cfg, overrides)
        if shape.kind == "train" and n_micro > 1:
            opt_factor = {"adamw": 24.0, "momentum_bf16": 10.0}[cfg.optimizer]
            u_bytes = opt_factor / 2.0 * pd_param_bytes  # bytes per bf16 param byte
            u_flops = 12.0 * pd_param_bytes / 2.0
            step = {
                "flops": n_micro * (terms["flops"]["full_micro"] - u_flops) + u_flops,
                "bytes": n_micro * (terms["bytes"]["full_micro"] - u_bytes) + u_bytes,
                "coll": n_micro * terms["coll"]["full_micro"],
                "coll_f32": n_micro * terms["coll_f32"]["full_micro"],
            }
        else:
            step = {k: terms[k]["full_micro"] for k in ("flops", "bytes", "coll", "coll_f32")}
        rec.update(
            status="ok",
            compile_s=round(time.time() - t0, 1),
            per_device=step,
            detail=terms,
            param_bytes_per_device=pd_param_bytes,
            n_micro=n_micro,
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-3000:])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--append", action="store_true")
    ap.add_argument("--calibrate", action="store_true", help="roofline calibration lowerings")
    args = ap.parse_args()

    if args.calibrate:
        archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
        shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
        results = []
        if args.append and os.path.exists(args.out):
            with open(args.out) as f:
                results = json.load(f)
        done = {(r["arch"], r["shape"]) for r in results}
        for arch in archs:
            for shape in shapes:
                if (arch, shape) in done:
                    continue
                print(f"=== calibrate {arch} x {shape} ===", flush=True)
                rec = calibrate_cell(arch, shape)
                print(f"    -> {rec['status']} ({rec.get('compile_s', 0)}s) "
                      f"{rec.get('error') or rec.get('reason') or ''}", flush=True)
                if rec["status"] == "ok":
                    pd = rec["per_device"]
                    print(f"    flops={pd['flops']:.3e} bytes={pd['bytes']:.3e} coll={pd['coll']:.3e}",
                          flush=True)
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
        n_err = sum(r["status"] == "error" for r in results)
        print(f"calibration complete -> {args.out}")
        return 1 if n_err else 0

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    if args.append and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = (arch, shape, "2x16x16" if mp else "16x16")
                if key in done:
                    continue
                print(f"=== dryrun {key} ===", flush=True)
                rec = run_cell(arch, shape, mp)
                status = rec["status"]
                extra = rec.get("reason") or rec.get("error") or ""
                print(f"    -> {status} ({rec.get('compile_s', 0)}s) {extra}", flush=True)
                if status == "ok":
                    c = rec["cost"]
                    print(
                        f"    flops={c.get('flops', 0):.3e} bytes={c.get('bytes accessed', 0):.3e} "
                        f"coll={rec['collectives']['total_bytes']:.3e}",
                        flush=True,
                    )
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"dryrun complete: {n_ok} ok, {n_skip} skip, {n_err} error -> {args.out}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
