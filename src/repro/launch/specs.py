"""ShapeDtypeStruct input stand-ins + sharding resolution for every cell.

``input_specs(cfg, shape, shd)`` returns (batch_structs, batch_shardings)
for the step kind the shape dictates.  No device allocation happens here —
the same pattern as the dry-run requires.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.decode import init_cache
from repro.models.lm import init_lm
from repro.sharding import AxisRules, unzip_params

ACT_DTYPE = jnp.bfloat16


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ArchConfig, shape: ShapeSpec, shd: AxisRules):
    B, S = shape.global_batch, shape.seq_len
    n_micro = cfg.microbatch
    assert B % max(n_micro, 1) == 0
    Bm = B // n_micro

    def lead(*dims):
        return (n_micro,) + dims if n_micro > 1 else dims

    def spec(*axes):
        logical = (None,) + axes if n_micro > 1 else axes
        return P(*logical)

    batch = {
        "tokens": _sds(lead(Bm, S), jnp.int32),
        "labels": _sds(lead(Bm, S), jnp.int32),
    }
    specs = {
        "tokens": spec("batch", None),
        "labels": spec("batch", None),
    }
    if cfg.encoder_decoder:
        batch["frames"] = _sds(lead(Bm, cfg.enc_seq_len, cfg.d_model), ACT_DTYPE)
        specs["frames"] = spec("batch", None, None)
    if cfg.mrope_sections is not None:
        batch["positions"] = _sds(lead(Bm, 3, S), jnp.int32)
        specs["positions"] = spec("batch", None, None)
    shards = {k: shd.sharding(specs[k], batch[k].shape) for k in batch}
    return batch, shards


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeSpec, shd: AxisRules):
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((B, S), jnp.int32)}
    specs = {"tokens": P("batch", None)}
    if cfg.encoder_decoder:
        batch["frames"] = _sds((B, cfg.enc_seq_len, cfg.d_model), ACT_DTYPE)
        specs["frames"] = P("batch", None, None)
    if cfg.mrope_sections is not None:
        batch["positions"] = _sds((B, 3, S), jnp.int32)
        specs["positions"] = P("batch", None, None)
    shards = {k: shd.sharding(specs[k], batch[k].shape) for k in batch}
    return batch, shards


def decode_batch_specs(cfg: ArchConfig, shape: ShapeSpec, shd: AxisRules):
    B = shape.global_batch
    batch = {"token": _sds((B,), jnp.int32)}
    specs = {"token": P("batch")}
    if cfg.mrope_sections is not None:
        batch["positions"] = _sds((B, 3), jnp.int32)
        specs["positions"] = P("batch", None)
    shards = {k: shd.sharding(specs[k], batch[k].shape) for k in batch}
    return batch, shards


def input_specs(cfg: ArchConfig, shape: ShapeSpec, shd: AxisRules):
    if shape.kind == "train":
        return train_batch_specs(cfg, shape, shd)
    if shape.kind == "prefill":
        return prefill_batch_specs(cfg, shape, shd)
    return decode_batch_specs(cfg, shape, shd)


# ---------------------------------------------------------------------------
# Param / optimizer / cache abstract trees with shardings
# ---------------------------------------------------------------------------


def param_structs(cfg: ArchConfig, shd: AxisRules, dtype=ACT_DTYPE):
    """(shape-structs, logical specs, NamedShardings) for the param tree."""
    captured = {}

    def f(key):
        tree = init_lm(key, cfg, dtype)
        vals, specs = unzip_params(tree)
        captured["specs"] = specs
        return vals

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    specs = captured["specs"]
    shards = shd.resolve_tree(shapes, specs) if shd.mesh is not None else None
    return shapes, specs, shards


def cache_structs(cfg: ArchConfig, shape: ShapeSpec, shd: AxisRules, dtype=ACT_DTYPE):
    captured = {}

    def f():
        tree = init_cache(cfg, shape.global_batch, shape.seq_len, dtype)
        vals, specs = unzip_params(tree)
        captured["specs"] = specs
        return vals

    shapes = jax.eval_shape(f)
    specs = captured["specs"]
    shards = shd.resolve_tree(shapes, specs) if shd.mesh is not None else None
    return shapes, specs, shards
