"""Serving launcher: batched prefill + decode with an RCC-managed KV page
table (DESIGN.md §Arch-applicability integration point #1).

Admission and KV-page allocation run as transactions through the RCC
engine's store (NOWAIT: an allocation conflict aborts and retries next
round — the natural policy for page grabbing).  The LM decodes with the
cache built by prefill.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, reduced_config
from repro.models.decode import lm_decode_step, lm_prefill
from repro.models.lm import init_lm
from repro.sharding import AxisRules, unzip_params


class PageTable:
    """KV page allocator backed by a lock-word store (OCC-free NOWAIT CAS).

    Pages are records; a page is free iff its lock word is zero.  A batch
    allocation is a transaction: CAS every requested page; any conflict
    releases and retries with a different page set (NOWAIT semantics).
    """

    def __init__(self, n_pages: int):
        self.locks = jnp.zeros((n_pages,), jnp.int32)
        self.n_pages = n_pages

    def alloc(self, n: int, owner: int, key) -> jnp.ndarray:
        for attempt in range(8):
            k = jax.random.fold_in(key, attempt)
            cand = jax.random.choice(k, self.n_pages, (n,), replace=False)
            free = self.locks[cand] == 0
            if bool(free.all()):
                self.locks = self.locks.at[cand].set(owner + 1)
                return cand
        raise RuntimeError("page table exhausted")

    def free(self, pages: jnp.ndarray):
        self.locks = self.locks.at[pages].set(0)

    @property
    def used(self) -> int:
        return int((self.locks != 0).sum())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    shd = AxisRules(None)
    params = unzip_params(init_lm(jax.random.PRNGKey(0), cfg, jnp.float32))[0]
    print(f"[serve] arch={cfg.name} params={cfg.param_count():,}")

    B, P, G = args.batch, args.prompt_len, args.gen_len
    total = P + G
    pt = PageTable(n_pages=4 * B * (total // args.page_size + 1))
    pages = {
        b: pt.alloc(total // args.page_size + 1, b, jax.random.PRNGKey(100 + b))
        for b in range(B)
    }
    print(f"[serve] admitted {B} requests; page table used={pt.used}/{pt.n_pages}")

    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.encoder_decoder:
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_seq_len, cfg.d_model))
    if cfg.mrope_sections is not None:
        batch["positions"] = jnp.broadcast_to(jnp.arange(P)[None, None], (B, 3, P)).astype(jnp.int32)

    t0 = time.time()
    prefill = jax.jit(lambda p, b: lm_prefill(p, cfg, shd, b, pad_to=total))
    logits, cache = prefill(params, batch)
    print(f"[serve] prefill {B}x{P} in {time.time()-t0:.2f}s")

    decode = jax.jit(lambda p, c, b: lm_decode_step(p, cfg, shd, c, b))
    tok = jnp.argmax(logits, -1)
    out = [tok]
    t0 = time.time()
    for i in range(G - 1):
        db = {"token": tok}
        if cfg.mrope_sections is not None:
            db["positions"] = jnp.full((B, 3), P + i, jnp.int32)
        logits, cache = decode(params, cache, db)
        tok = jnp.argmax(logits, -1)
        out.append(tok)
    dt = time.time() - t0
    toks = B * (G - 1)
    print(f"[serve] decoded {toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s)")
    for b in range(B):
        pt.free(pages[b])
    print(f"[serve] released pages; page table used={pt.used}")
    seq = jnp.stack(out, 1)
    assert bool(jnp.isfinite(logits).all()) and seq.shape == (B, G)
    print("[serve] ok")


if __name__ == "__main__":
    main()
