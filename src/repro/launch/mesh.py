"""Production mesh construction.

A function (NOT a module-level constant) so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (single pod, 256 chips) or 2x16x16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py sets this automatically)"
        )
    dev = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests/examples)."""
    devices = jax.devices()[: data * model]
    dev = np.asarray(devices).reshape((data, model))
    return jax.sharding.Mesh(dev, ("data", "model"))
