"""Global lowering flags + scan wrapper.

UNROLL_SCANS: when True, every layer/microbatch scan is fully unrolled at
lowering time.  Production uses scan (compact HLO, fast compiles); the
roofline pass unrolls so XLA cost_analysis counts every executed iteration
(scan bodies are otherwise counted once — verified empirically).
"""
from __future__ import annotations

import jax

UNROLL_SCANS = False

# Route full-sequence attention through the Pallas TPU kernel
# (kernels/flash_attention.py).  Default off: the XLA scan-flash path is the
# portable production fallback and the only executable one on CPU; on a real
# TPU set this True (kernels validate against ref.py in interpret mode).
USE_PALLAS_ATTENTION = False


def scan(f, init, xs=None, length=None):
    if UNROLL_SCANS:
        return jax.lax.scan(f, init, xs, length, unroll=True)
    return jax.lax.scan(f, init, xs, length)
