"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on TPU set
``interpret=False`` (the wrappers auto-detect).  The LM stack can route its
attention through `attention_op` with cfg-level opt-in; the RCC engine can
route arbitration through `arbiter_op`.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention import flash_attention
from repro.kernels.lock_arbiter import lock_arbiter
from repro.kernels.mvcc_version_select import mvcc_version_select
from repro.kernels.rglru_scan import rglru_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def attention_op(q, k, v, *, causal=True, block_q=128, block_k=128):
    """(B, S, H, Dh) layout in, matching models/lm.py conventions."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention(
        qt, kt, vt, causal=causal, block_q=block_q, block_k=block_k, interpret=not _on_tpu()
    )
    return out.transpose(0, 2, 1, 3)


@jax.jit
def version_select_op(wts_hi, wts_lo, ctts_hi, ctts_lo, lock_hi, lock_lo):
    return mvcc_version_select(
        wts_hi, wts_lo, ctts_hi, ctts_lo, lock_hi, lock_lo, interpret=not _on_tpu()
    )


@jax.jit
def arbiter_op(keys, prio, active):
    m = keys.shape[1]
    block = max(128, 1 << (m - 1).bit_length())
    return lock_arbiter(keys, prio, active, block_m=block, interpret=not _on_tpu())


@jax.jit
def rglru_op(a, b, h0):
    return rglru_scan(a, b, h0, interpret=not _on_tpu())
