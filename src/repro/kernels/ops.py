"""The kernel plane: backend dispatch for the engine's Pallas hot paths.

The engine tick has three inner loops hot enough to fuse (ROADMAP "fast as
the hardware allows"): per-key CAS arbitration, the MVCC Cond R1/R2 version
pick, and the doorbell-batched multi-array row gather.  Each has a Pallas
kernel (lock_arbiter / mvcc_version_select / multi_read) and a pure-jnp
reference implementation; THIS module owns the choice between them.

A *kernel plane* is one of

  * ``"jnp"``            — the reference gather/scatter path (always available)
  * ``"pallas"``         — compiled Pallas kernels (TPU/GPU)
  * ``"pallas_interpret"`` — the same kernels in interpret mode (CPU CI:
    exercises the kernel code paths without a TPU)

``"auto"`` resolves per backend at plan time: Pallas on TPU/GPU, jnp on
CPU.  The plane threads through ``ExperimentSpec.kernel_plane`` ->
``GridSpec`` -> ``EngineConfig.kernel_plane`` as a STATIC field, so it is
part of the compiled program identity and never traced.

Parity contract (DESIGN.md §9, pinned by tests/test_kernel_parity.py and
the kernel-parity CI job): for every protocol, integer counters under a
Pallas plane are bitwise-equal to the jnp plane.  The kernels therefore
implement *exactly* the reference semantics — lexicographic-min
arbitration with no index tiebreak, and exact int32 one-hot gathers
(never an f32 MXU matmul).

The LM stack's flash-attention wrapper (`attention_op`) also lives here:
same backend detection, cfg-level opt-in from models/lm.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.arbiter import scatter_min_winner
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lock_arbiter import lock_arbiter
from repro.kernels.multi_read import multi_read
from repro.kernels.mvcc_version_select import mvcc_version_select

JNP = "jnp"
PALLAS = "pallas"
PALLAS_INTERPRET = "pallas_interpret"
KERNEL_PLANES = (JNP, PALLAS, PALLAS_INTERPRET)
AUTO = "auto"


def _accel() -> bool:
    return jax.default_backend() in ("tpu", "gpu")


def default_interpret() -> bool:
    """Backend-detected ``interpret`` default for the raw kernel entry
    points (kernels must not hardcode it in their signatures)."""
    return not _accel()


def default_plane() -> str:
    """What ``"auto"`` resolves to on this process's default backend."""
    return PALLAS if _accel() else JNP


def resolve_plane(plane: str | None) -> str:
    """Validate/resolve a kernel-plane knob (``None``/"auto" -> backend)."""
    if plane is None or plane == AUTO:
        return default_plane()
    if plane not in KERNEL_PLANES:
        raise ValueError(
            f"kernel_plane={plane!r}: pass 'auto' or one of {KERNEL_PLANES}"
        )
    return plane


def is_pallas(plane: str) -> bool:
    return plane in (PALLAS, PALLAS_INTERPRET)


def plane_interpret(plane: str) -> bool:
    """The ``interpret=`` flag a Pallas plane lowers with."""
    return plane != PALLAS


def describe_plane(plane: str) -> str:
    return {
        JNP: "pure-jnp reference (gather/scatter)",
        PALLAS: "compiled Pallas kernels",
        PALLAS_INTERPRET: "Pallas kernels, interpret mode (CPU CI)",
    }[plane]


# ---------------------------------------------------------------------------
# Engine hot-path dispatch (plane is STATIC: Python branches are free)
# ---------------------------------------------------------------------------


def cas_arbitrate(keys, prio_hi, prio_lo, active, n_records: int, *, plane: str = JNP):
    """Per-key lexicographic-min CAS arbitration over a flat request batch.

    keys/prio_hi/prio_lo (M,) int32, active (M,) bool -> won (M,) bool,
    bitwise-equal across planes (``scatter_min_winner`` semantics)."""
    if not is_pallas(plane):
        return scatter_min_winner(keys, prio_hi, prio_lo, active, n_records)
    won = lock_arbiter(
        keys[None], prio_hi[None], prio_lo[None], active[None],
        interpret=plane_interpret(plane),
    )
    return won[0]


def version_select(wts_hi, wts_lo, ctts_hi, ctts_lo, lock_hi, lock_lo, *, plane: str = JNP):
    """MVCC Cond R1 slot pick + Cond R2 lock check over a flat op batch.

    wts_* (M, S), the rest (M,) int32 -> (found, slot, r2_ok)."""
    if not is_pallas(plane):
        from repro.kernels.ref import mvcc_version_select_ref

        return mvcc_version_select_ref(wts_hi, wts_lo, ctts_hi, ctts_lo, lock_hi, lock_lo)
    return mvcc_version_select(
        wts_hi, wts_lo, ctts_hi, ctts_lo, lock_hi, lock_lo,
        interpret=plane_interpret(plane),
    )


def gather_rows_batch(table, keys, *, plane: str = JNP):
    """Packed-row gather: table (R, A) int32 at keys (M,) -> (M, A)."""
    if not is_pallas(plane):
        return table[keys]
    return multi_read(table, keys, interpret=plane_interpret(plane))


def pack_rows(arrs):
    """Flatten several (R, ...) int32 arrays into one (R, A) packed table
    (the doorbell payload) + the per-array flat widths."""
    R = arrs[0].shape[0]
    cols = [a.reshape(R, -1) for a in arrs]
    table = cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)
    return table, [c.shape[1] for c in cols]


def unpack_rows(out, arrs, widths, keys_shape):
    """Split a gathered (M, A) packed payload back into per-array results
    shaped ``keys_shape + arr.shape[1:]``."""
    outs, pos = [], 0
    for a, w in zip(arrs, widths):
        outs.append(out[:, pos : pos + w].reshape(keys_shape + a.shape[1:]))
        pos += w
    return tuple(outs)


def gather_many(arrs, keys, *, plane: str = JNP):
    """Doorbell-batched multi-array gather: ONE packed kernel dispatch for
    several store arrays at the same keys (engine.read_rows_many's kernel
    path).  Returns a tuple shaped like the per-array gathers."""
    kf = keys.reshape(-1)
    table, widths = pack_rows(arrs)
    out = gather_rows_batch(table, kf, plane=plane)
    return unpack_rows(out, arrs, widths, keys.shape)


# ---------------------------------------------------------------------------
# LM-stack attention wrapper (unchanged contract)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def attention_op(q, k, v, *, causal=True, block_q=128, block_k=128):
    """(B, S, H, Dh) layout in, matching models/lm.py conventions."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention(
        qt, kt, vt, causal=causal, block_q=block_q, block_k=block_k,
        interpret=default_interpret(),
    )
    return out.transpose(0, 2, 1, 3)
