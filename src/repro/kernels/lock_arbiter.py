"""Lock-CAS arbitration — Pallas TPU kernel.

Models the owning node's RNIC serializing concurrent CAS verbs: within each
owner's request block, request i wins iff no active request j on the same
key has a lexicographically smaller (prio_hi, prio_lo).  Requests are
grouped per owning node (the grid axis), so arbitration is all-pairs within
a (block_m x block_m) VPU tile — the TPU-native replacement for the
GPU-style atomic-CAS loop.

Semantics are EXACTLY ``repro.core.arbiter.scatter_min_winner``: pure
lexicographic minimum, no index tiebreak — engine callers guarantee unique
(prio_hi, prio_lo) pairs among active requests (timestamp pairs, or a
hashed hi word with the unique logical op index as the lo word), which is
what makes the winner unique and the kernel plane bitwise-interchangeable
with the jnp plane.

``interpret=None`` (the default) defers to backend detection in
``repro.kernels.ops`` — compiled on TPU/GPU, interpret mode on CPU CI.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(keys_ref, hi_ref, lo_ref, active_ref, won_ref):
    keys = keys_ref[0]  # (bm,)
    hi = hi_ref[0]
    lo = lo_ref[0]
    act = active_ref[0]
    same = keys[:, None] == keys[None, :]
    beats_me = (
        same
        & act[None, :]
        & ((hi[None, :] < hi[:, None]) | ((hi[None, :] == hi[:, None]) & (lo[None, :] < lo[:, None])))
    )
    won_ref[0] = act & ~beats_me.any(axis=1)


def lock_arbiter(keys, prio_hi, prio_lo, active, *, block_m: int | None = None, interpret=None):
    """Per-owner arbitration. keys/prio_hi/prio_lo (G, M) int32, active
    (G, M) bool -> won (G, M) bool.  G = owner groups (nodes); M = max
    requests per owner.  A request wins iff it is the per-key lexicographic
    (prio_hi, prio_lo) minimum among active requests in its group (ties ->
    multiple winners, exactly as ``scatter_min_winner``)."""
    if interpret is None:
        from repro.kernels import ops

        interpret = ops.default_interpret()
    G, M = keys.shape
    if block_m is None:
        block_m = max(128, 1 << (M - 1).bit_length())
    pad = (-M) % block_m
    if pad:
        keys = jnp.pad(keys, ((0, 0), (0, pad)), constant_values=-1)
        prio_hi = jnp.pad(prio_hi, ((0, 0), (0, pad)))
        prio_lo = jnp.pad(prio_lo, ((0, 0), (0, pad)))
        active = jnp.pad(active, ((0, 0), (0, pad)))
    Mp = M + pad
    assert Mp == block_m, "per-owner request count must fit one arbitration tile"
    won = pl.pallas_call(
        _kernel,
        grid=(G,),
        in_specs=[
            pl.BlockSpec((1, Mp), lambda g: (g, 0)),
            pl.BlockSpec((1, Mp), lambda g: (g, 0)),
            pl.BlockSpec((1, Mp), lambda g: (g, 0)),
            pl.BlockSpec((1, Mp), lambda g: (g, 0)),
        ],
        out_specs=pl.BlockSpec((1, Mp), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((G, Mp), jnp.bool_),
        interpret=interpret,
    )(keys, prio_hi, prio_lo, active)
    return won[:, :M]
