"""Lock-CAS arbitration — Pallas TPU kernel.

Models the owning node's RNIC serializing concurrent CAS verbs: within each
owner's request block, request i wins iff no active request j on the same
key has a smaller (prio, j).  Requests are grouped per owning node (the
grid axis), so arbitration is all-pairs within a (block_m x block_m) VPU
tile — the TPU-native replacement for the GPU-style atomic-CAS loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(keys_ref, prio_ref, active_ref, won_ref):
    keys = keys_ref[0]  # (bm,)
    prio = prio_ref[0]
    act = active_ref[0]
    bm = keys.shape[0]
    same = keys[:, None] == keys[None, :]
    jdx = jax.lax.broadcasted_iota(jnp.int32, (bm, bm), 1)
    idx = jax.lax.broadcasted_iota(jnp.int32, (bm, bm), 0)
    beats_me = (
        same
        & act[None, :]
        & ((prio[None, :] < prio[:, None]) | ((prio[None, :] == prio[:, None]) & (jdx < idx)))
    )
    won_ref[0] = act & ~beats_me.any(axis=1)


def lock_arbiter(keys, prio, active, *, block_m: int = 256, interpret: bool = True):
    """Per-owner arbitration. keys/prio (G, M) int32, active (G, M) bool ->
    won (G, M) bool.  G = owner groups (nodes); M = max requests per owner.
    Exactly one winner per distinct key per group."""
    G, M = keys.shape
    pad = (-M) % block_m
    if pad:
        keys = jnp.pad(keys, ((0, 0), (0, pad)), constant_values=-1)
        prio = jnp.pad(prio, ((0, 0), (0, pad)))
        active = jnp.pad(active, ((0, 0), (0, pad)))
    Mp = M + pad
    assert Mp == block_m, "per-owner request count must fit one arbitration tile"
    won = pl.pallas_call(
        _kernel,
        grid=(G,),
        in_specs=[
            pl.BlockSpec((1, Mp), lambda g: (g, 0)),
            pl.BlockSpec((1, Mp), lambda g: (g, 0)),
            pl.BlockSpec((1, Mp), lambda g: (g, 0)),
        ],
        out_specs=pl.BlockSpec((1, Mp), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((G, Mp), jnp.bool_),
        interpret=interpret,
    )(keys, prio, active)
    return won[:, :M]
