# The kernel plane (DESIGN.md §9): Pallas kernels for the engine's hot
# paths + pure-jnp references, selected by repro.kernels.ops.  Every
# module here must be imported from outside the package (ops dispatch,
# models/lm.py, ...) — scripts/check_api_boundary.py's dead-module gate
# fails on vestigial kernels (ref.py, the test oracle module, is exempt).
