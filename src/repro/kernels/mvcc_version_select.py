"""MVCC version selection — Pallas TPU kernel.

RCC's per-op read hot loop (paper §4.4): for a batch of read requests,
pick the slot with the largest wts < ctts among the S static version slots
(Cond R1) and check Cond R2 (lock free or lock > ctts).  TPU-native
layout: requests tile the sublane axis (block_m), the version slots ride
the lane axis — pure VPU compares, no gathers.  The slot count comes from
the input shape (``mvcc_slots`` is an EngineConfig ablation knob, not a
kernel constant).

``interpret=None`` (the default) defers to backend detection in
``repro.kernels.ops`` — compiled on TPU/GPU, interpret mode on CPU CI.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_MIN = -(2**31)


def _kernel(wts_hi_ref, wts_lo_ref, ctts_hi_ref, ctts_lo_ref, lk_hi_ref, lk_lo_ref,
            found_ref, slot_ref, ok_ref):
    wh, wl = wts_hi_ref[...], wts_lo_ref[...]  # (bm, S)
    ch, cl = ctts_hi_ref[...][:, None], ctts_lo_ref[...][:, None]  # (bm, 1)
    lh, ll = lk_hi_ref[...], lk_lo_ref[...]  # (bm,)
    # Cond R1: largest (wh, wl) < (ch, cl), excluding empty (0,0) slots
    lt = (wh < ch) | ((wh == ch) & (wl < cl))
    occupied = (wh != 0) | (wl != 0)
    cand = lt & occupied
    bh = jnp.where(cand, wh, _MIN)
    best_h = bh.max(axis=1, keepdims=True)
    at_h = cand & (wh == best_h)
    bl = jnp.where(at_h, wl, _MIN)
    best_l = bl.max(axis=1, keepdims=True)
    winner = at_h & (wl == best_l)
    found_ref[...] = cand.any(axis=1)
    slot_ref[...] = jnp.argmax(winner, axis=1).astype(jnp.int32)
    # Cond R2: lock free, or lock (writer tts) ordered after ctts
    free = (lh == 0) & (ll == 0)
    after = (ch[:, 0] < lh) | ((ch[:, 0] == lh) & (cl[:, 0] < ll))
    ok_ref[...] = free | after


def mvcc_version_select(wts_hi, wts_lo, ctts_hi, ctts_lo, lock_hi, lock_lo,
                        *, block_m: int = 256, interpret=None):
    """wts_* (M, S), the rest (M,) int32 -> (found (M,), slot (M,), r2_ok (M,))."""
    if interpret is None:
        from repro.kernels import ops

        interpret = ops.default_interpret()
    M, S = wts_hi.shape
    pad = (-M) % block_m
    if pad:
        def z2(a):
            return jnp.pad(a, ((0, pad), (0, 0)))

        def z1(a):
            return jnp.pad(a, ((0, pad),))

        wts_hi, wts_lo = z2(wts_hi), z2(wts_lo)
        ctts_hi, ctts_lo, lock_hi, lock_lo = map(z1, (ctts_hi, ctts_lo, lock_hi, lock_lo))
    Mp = M + pad
    grid = (Mp // block_m,)
    s2 = pl.BlockSpec((block_m, S), lambda i: (i, 0))
    s1 = pl.BlockSpec((block_m,), lambda i: (i,))
    found, slot, ok = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[s2, s2, s1, s1, s1, s1],
        out_specs=[s1, s1, s1],
        out_shape=[
            jax.ShapeDtypeStruct((Mp,), jnp.bool_),
            jax.ShapeDtypeStruct((Mp,), jnp.int32),
            jax.ShapeDtypeStruct((Mp,), jnp.bool_),
        ],
        interpret=interpret,
    )(wts_hi, wts_lo, ctts_hi, ctts_lo, lock_hi, lock_lo)
    return found[:M], slot[:M], ok[:M]
