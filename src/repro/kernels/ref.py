"""Pure-jnp oracles for every Pallas kernel (tests assert against these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_MIN = -(2**31)


def flash_attention_ref(q, k, v, *, causal=True):
    """q/k/v (B, H, S, Dh) -> (B, H, S, Dh) — naive O(S^2) fp32 softmax."""
    Dh = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / jnp.sqrt(Dh)
    if causal:
        Sq, Sk = q.shape[2], k.shape[2]
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


def mvcc_version_select_ref(wts_hi, wts_lo, ctts_hi, ctts_lo, lock_hi, lock_lo):
    ch, cl = ctts_hi[:, None], ctts_lo[:, None]
    lt = (wts_hi < ch) | ((wts_hi == ch) & (wts_lo < cl))
    occ = (wts_hi != 0) | (wts_lo != 0)
    cand = lt & occ
    bh = jnp.where(cand, wts_hi, _MIN).max(1, keepdims=True)
    at_h = cand & (wts_hi == bh)
    bl = jnp.where(at_h, wts_lo, _MIN).max(1, keepdims=True)
    winner = at_h & (wts_lo == bl)
    found = cand.any(1)
    slot = jnp.argmax(winner, axis=1).astype(jnp.int32)
    free = (lock_hi == 0) & (lock_lo == 0)
    after = (ctts_hi < lock_hi) | ((ctts_hi == lock_hi) & (ctts_lo < lock_lo))
    return found, slot, free | after


def lock_arbiter_ref(keys, prio_hi, prio_lo, active):
    """(G, M) -> won (G, M): per-group per-key lexicographic
    (prio_hi, prio_lo) minimum wins — ``scatter_min_winner`` semantics, no
    index tiebreak (callers guarantee unique pairs for winner uniqueness)."""
    same = keys[:, :, None] == keys[:, None, :]
    hi_j, hi_i = prio_hi[:, None, :], prio_hi[:, :, None]
    lo_j, lo_i = prio_lo[:, None, :], prio_lo[:, :, None]
    beats = same & active[:, None, :] & ((hi_j < hi_i) | ((hi_j == hi_i) & (lo_j < lo_i)))
    return active & ~beats.any(-1)


def multi_read_ref(table, keys):
    """table (R, A), keys (M,) -> (M, A); negative (padding) keys gather 0."""
    out = table[jnp.clip(keys, 0, table.shape[0] - 1)]
    return jnp.where((keys >= 0)[:, None], out, 0)
