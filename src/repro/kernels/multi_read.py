"""Doorbell-batched multi-read — Pallas TPU kernel.

One RDMA doorbell posts several dependent READs for the same key set
(paper §4.2); the engine's analogue is ``read_rows_many`` /
``planes.node_read_batch``: several store arrays packed along a feature
axis and gathered at one batch of row ids.  This kernel fuses that gather:
the packed table streams through VMEM one row-block at a time while each
key block accumulates its matching rows.

The accumulation is an EXACT int32 one-hot select-and-sum (each key
matches exactly one table row, every other contribution is the int32
constant 0) — never a matmul, whose f32 MXU path would silently round
counters above 2^24.  That exactness is what keeps the kernel plane
bitwise-equal to the jnp gather plane.

``interpret=None`` (the default) defers to backend detection in
``repro.kernels.ops`` — compiled on TPU/GPU, interpret mode on CPU CI.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(keys_ref, table_ref, out_ref, *, block_r: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    k = keys_ref[...]  # (bm,)
    tab = table_ref[...]  # (br, A)
    rel = k - j * block_r  # key's offset into this row block (or out of range)
    onehot = rel[:, None] == jax.lax.broadcasted_iota(jnp.int32, (k.shape[0], block_r), 1)
    # exact int32 accumulation: select-and-sum, NOT a (f32 MXU) matmul
    out_ref[...] += jnp.where(onehot[:, :, None], tab[None], 0).sum(axis=1)


def multi_read(table, keys, *, block_m: int = 128, block_r: int = 512, interpret=None):
    """Gather packed rows: table (R, A) int32, keys (M,) int32 in [0, R)
    -> (M, A) int32 == table[keys].  Negative keys (padding) return zeros."""
    if interpret is None:
        from repro.kernels import ops

        interpret = ops.default_interpret()
    M = keys.shape[0]
    R, A = table.shape
    block_m = min(block_m, max(8, 1 << (M - 1).bit_length()))
    block_r = min(block_r, max(8, 1 << (R - 1).bit_length()))
    pad_m = (-M) % block_m
    pad_r = (-R) % block_r
    if pad_m:
        keys = jnp.pad(keys, ((0, pad_m),), constant_values=-1)
    if pad_r:
        table = jnp.pad(table, ((0, pad_r), (0, 0)))
    Mp, Rp = M + pad_m, R + pad_r
    out = pl.pallas_call(
        lambda kr, tr, orf: _kernel(kr, tr, orf, block_r=block_r),
        grid=(Mp // block_m, Rp // block_r),
        in_specs=[
            pl.BlockSpec((block_m,), lambda i, j: (i,)),
            pl.BlockSpec((block_r, A), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, A), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, A), jnp.int32),
        interpret=interpret,
    )(keys, table)
    return out[:M]
