"""RG-LRU linear recurrence — Pallas TPU kernel.

h_t = a_t * h_{t-1} + b_t over time, channels vectorized on lanes.  Time is
blocked on the minor grid axis; the carry h lives in VMEM scratch and
persists across time blocks (sequential revisiting), so HBM traffic is one
read of (a, b) and one write of h per element — the recurrence bottleneck
for recurrentgemma's long_500k decode/prefill path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def _kernel(a_ref, b_ref, h0_ref, out_ref, carry_ref, *, block_t):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        carry_ref[...] = h0_ref[0]

    a = a_ref[0]  # (bt, W)
    b = b_ref[0]
    h = carry_ref[...]  # (W,)

    def step(i, hc):
        hn = a[i] * hc + b[i]
        out_ref[0, i, :] = hn.astype(out_ref.dtype)
        return hn

    h = jax.lax.fori_loop(0, block_t, step, h)
    carry_ref[...] = h


def rglru_scan(a, b, h0, *, block_t: int = 128, interpret: bool = True):
    """a/b (B, T, W) fp32, h0 (B, W) -> h (B, T, W)."""
    B, T, W = a.shape
    pad = (-T) % block_t
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    grid = (B, Tp // block_t)
    out = pl.pallas_call(
        functools.partial(_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, W), lambda bi, ti: (bi, ti, 0)),
            pl.BlockSpec((1, block_t, W), lambda bi, ti: (bi, ti, 0)),
            pl.BlockSpec((1, W), lambda bi, ti: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_t, W), lambda bi, ti: (bi, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Tp, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((W,), jnp.float32)] if pltpu else None,
        interpret=interpret,
    )(a, b, h0)
    return out[:, :T]
