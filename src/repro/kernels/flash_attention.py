"""Blocked causal flash attention — Pallas TPU kernel.

TPU-native design (DESIGN.md §7): the Q tile (block_q x Dh) stays resident
in VMEM; K/V stream through as (block_k x Dh) tiles on the minor grid axis;
online-softmax statistics (m, l) and the output accumulator live in VMEM
scratch and persist across K/V steps.  MXU-aligned tiles (multiples of 128
on the contracted dims).  Causal masking is positional; fully-masked K/V
blocks are skipped via pl.when (no MXU work issued).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, causal, block_q, block_k, seq_k):
    i = pl.program_id(2)  # q block
    j = pl.program_id(3)  # kv block
    n_j = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = i * block_q
    k_start = j * block_k
    # skip fully-masked blocks (strictly above the causal diagonal)
    @pl.when((not causal) | (k_start <= q_start + block_q - 1))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, Dh)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, Dh)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = cols < seq_k
        if causal:
            mask &= cols <= rows
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(j == n_j - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q, k, v, *, causal: bool = True, block_q: int = 128, block_k: int = 128, interpret: bool = True
):
    """q/k/v (B, H, S, Dh) -> (B, H, S, Dh).  H pre-expanded (GQA repeat)."""
    B, H, Sq, Dh = q.shape
    Sk = k.shape[2]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sq_p, Sk_p = Sq + pad_q, Sk + pad_k
    grid = (B, H, Sq_p // block_q, Sk_p // block_k)
    scale = 1.0 / (Dh ** 0.5)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, block_q=block_q, block_k=block_k, seq_k=Sk
    )
    scratch = [
        pltpu.VMEM((block_q,), jnp.float32) if pltpu else None,
        pltpu.VMEM((block_q,), jnp.float32) if pltpu else None,
        pltpu.VMEM((block_q, Dh), jnp.float32) if pltpu else None,
    ]
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, Dh), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, Dh), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dh), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_p, Dh), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
