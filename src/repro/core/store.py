"""Distributed tuple store: records + per-protocol metadata (paper Fig. 3).

The store is a node-partitioned key-value array set; global key k lives on
node k // records_per_node (records are range-partitioned, as in RCC where
each benchmark partitions records across nodes).  Metadata is physically
co-located with the record, mirroring RCC's single-READ tuple fetch.

Layouts (per protocol, paper Fig. 3):
  NOWAIT   | lock(2w)            | record |
  WAITDIE  | tts=lock(2w)        | record |
  OCC      | lock(2w) | seq(1w)  | record |
  MVCC     | tts(2w) | rts(2w) | wts[4](8w) | record[4] |
  SUNDIAL  | lock(2w) | rts(2w) | wts(2w) | record |

`ver` is a protocol-independent commit-version counter used only by the
serializability validator (never read by protocol logic).
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.core.timestamps import TS

N_VERSIONS = 4  # MVCC static version slots (paper §4.4: four)


def init_store(protocol: str, n_records: int, rw: int, init_value: int = 0, n_versions: int = N_VERSIONS) -> Dict:
    def z(*s):
        return jnp.zeros(s, jnp.int32)

    store = {
        "lock_hi": z(n_records),
        "lock_lo": z(n_records),
        "ver": z(n_records),
    }
    if protocol == "mvcc":
        # slot 0 seeded as the initial committed version (wts = (0, 1))
        store["wts_hi"] = z(n_records, n_versions)
        store["wts_lo"] = z(n_records, n_versions).at[:, 0].set(1)
        store["rts_hi"] = z(n_records)
        store["rts_lo"] = z(n_records)
        store["vdata"] = jnp.full((n_records, n_versions, rw), init_value, jnp.int32)
        store["vver"] = z(n_records, n_versions)
    else:
        store["data"] = jnp.full((n_records, rw), init_value, jnp.int32)
    if protocol == "occ":
        store["seq"] = z(n_records)
    if protocol == "sundial":
        store["wts_hi"] = z(n_records)
        store["wts_lo"] = z(n_records)
        store["rts_hi"] = z(n_records)
        store["rts_lo"] = z(n_records)
    return store


def store_lock(store) -> TS:
    return TS(store["lock_hi"], store["lock_lo"])


def set_lock(store, ts: TS):
    store = dict(store)
    store["lock_hi"], store["lock_lo"] = ts.hi, ts.lo
    return store


def owner_of(keys, records_per_node):
    """Global key -> owning node id."""
    return keys // records_per_node
