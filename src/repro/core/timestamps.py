"""Two-word (hi, lo) transaction timestamps.

The paper (§4.3) constructs globally-unique timestamps from the local clock
with machine/thread/coroutine ids appended in the low-order bits, avoiding
global clock sync (NTP/PTP).  We keep the clock in `hi` (int32 logical
local clock) and the unique id in `lo` (the LOGICAL slot id + 1, assigned
by ``engine.regen_txns`` so bucket-padded runs stay id-stable), and
compare lexicographically.  MVCC's clock-drift adjustment (§4.4) bumps the
local clock whenever a larger remote wts/rts is observed.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

TS_FREE = jnp.int32(0)  # hi==0 && lo==0 => lock free / no version
INT_MAX = jnp.int32(2**31 - 1)


class TS(NamedTuple):
    hi: jnp.ndarray
    lo: jnp.ndarray

    def __repr__(self):
        return f"TS(hi={self.hi}, lo={self.lo})"


def ts_lt(a: TS, b: TS):
    return (a.hi < b.hi) | ((a.hi == b.hi) & (a.lo < b.lo))


def ts_le(a: TS, b: TS):
    return (a.hi < b.hi) | ((a.hi == b.hi) & (a.lo <= b.lo))


def ts_eq(a: TS, b: TS):
    return (a.hi == b.hi) & (a.lo == b.lo)


def ts_is_zero(a: TS):
    return (a.hi == 0) & (a.lo == 0)


def ts_zero_like(a: TS):
    return TS(jnp.zeros_like(a.hi), jnp.zeros_like(a.lo))


def ts_max(a: TS, b: TS):
    a_ge = ~ts_lt(a, b)
    return TS(jnp.where(a_ge, a.hi, b.hi), jnp.where(a_ge, a.lo, b.lo))


def ts_min(a: TS, b: TS):
    a_le = ts_le(a, b)
    return TS(jnp.where(a_le, a.hi, b.hi), jnp.where(a_le, a.lo, b.lo))


def ts_where(cond, a: TS, b: TS):
    return TS(jnp.where(cond, a.hi, b.hi), jnp.where(cond, a.lo, b.lo))


def ts_gather(ts: TS, idx):
    return TS(ts.hi[idx], ts.lo[idx])
