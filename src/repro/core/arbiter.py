"""Deterministic arbitration of concurrent atomic ops (the RNIC's job).

When several coordinators CAS the same lock word in the same round, the
remote RNIC serializes them; exactly one wins.  We arbitrate by (priority,
timestamp) with a two-pass scatter-min over the (hi, lo) timestamp words —
deterministic, vectorized, and equivalent to an arrival order that favors
older transactions (a fairness choice the 2PL literature prefers; for
protocols where arrival order should look random, callers pass a hashed
priority instead of the timestamp).
"""
from __future__ import annotations

import jax.numpy as jnp


def scatter_min_winner(keys, prio_hi, prio_lo, active, n_records):
    """Among active requests, find the per-key minimum (prio_hi, prio_lo).

    keys (M,) int32 in [0, n_records); returns (M,) bool — is this request
    the unique winner for its key.  (prio_hi, prio_lo) must be unique among
    active requests for winner uniqueness.
    """
    big = jnp.int32(2**31 - 1)
    kh = jnp.where(active, prio_hi, big)
    best_hi = jnp.full((n_records,), big, jnp.int32).at[keys].min(kh, mode="drop")
    hi_ok = active & (prio_hi == best_hi[keys])
    kl = jnp.where(hi_ok, prio_lo, big)
    best_lo = jnp.full((n_records,), big, jnp.int32).at[keys].min(kl, mode="drop")
    return hi_ok & (prio_lo == best_lo[keys])


def requests_per_node(keys, active, records_per_node, n_nodes):
    """This tick's per-destination-node request counts (for queue delays)."""
    dest = jnp.clip(keys // records_per_node, 0, n_nodes - 1)
    cnt = jnp.zeros((n_nodes,), jnp.int32).at[dest].add(active.astype(jnp.int32), mode="drop")
    return cnt, dest


def hash_prio(ts_lo, salt):
    """Deterministic pseudo-random priority (models arrival order)."""
    x = (ts_lo.astype(jnp.uint32) * jnp.uint32(2654435761)) ^ jnp.uint32(salt)
    x = x ^ (x >> 16)
    return (x & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)
