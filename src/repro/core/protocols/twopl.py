"""Shared 2PL machinery: NOWAIT and WAITDIE (paper §4.2, §4.3).

Stage machine (declared as a rounds.StageSpec table):
  LOCK -> EXEC -> LOG -> COMMIT -> (done, regen)
    \\-> ABREL (release partial locks) -> retry same txn

NOWAIT: any lock conflict aborts immediately.
WAITDIE: on conflict, compare timestamps with the holder — strictly older
requesters WAIT (RPC: parked on the owner's wait-list, no re-issued rounds;
one-sided: re-post CAS+READ every round, consuming NIC capacity — exactly
the paper's §4.3 asymmetry), younger requesters DIE (abort, retry with the
ORIGINAL timestamp so they eventually age to the front).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import registry
from repro.core import rounds
from repro.core.costmodel import (
    RPC,
    ST_COMMIT,
    ST_EXEC,
    ST_LOCK,
    ST_LOG,
    ST_RELEASE,
)
from repro.core.rounds import StageOut, StageSpec
from repro.core.timestamps import TS, ts_is_zero, ts_lt

S_LOCK, S_EXEC, S_LOG, S_COMMIT, S_ABREL = range(5)


def _lock_effect(wait_die: bool):
    """Arbitrated CAS + fetch-under-lock with the NOWAIT/WAITDIE conflict
    rule.  RPC waiters are parked server-side (``served`` accumulates);
    one-sided waiters re-post CAS+READ every tick.  The primitive may be a
    traced scalar (batched sweep), so both planes run the same ops and the
    plane-specific bookkeeping is selected with jnp.where."""

    def effect(ec, cm, wl, st, store, in_l, served, salt):
        is_rpc_l = jnp.asarray(ec.hybrid[ST_LOCK] == RPC)
        st = dict(st)
        pend = in_l[:, None] & st["valid"] & ~st["locked"]
        acc = served & is_rpc_l
        # under a parked RPC waiter st["served"] stays set, while the
        # one-sided plane never accumulates it — pend re-posts every tick
        contenders = jnp.where(is_rpc_l, pend & (st["served"] | acc), served)

        if wait_die:
            prio_hi = jnp.broadcast_to(st["ts_hi"][:, None], contenders.shape)
            prio_lo = jnp.broadcast_to(st["ts_lo"][:, None], contenders.shape)
        else:
            # hashed priority models arrival order; the UNIQUE logical op
            # index as the lo word guarantees exactly one arbitration winner
            # per key (hash collisions would otherwise break lock
            # exclusivity) and keeps draws bucket-padding-invariant
            base = eng.op_index(ec, contenders.shape[1])
            prio_hi = eng.hash_prio(base + st["ts_lo"][:, None], salt + 1)
            prio_lo = base
        won, store = eng.try_lock(ec, store, st, contenders, prio_hi, prio_lo)
        st["locked"] = st["locked"] | won
        # fetch records under freshly-won locks (CAS+READ / handler reply):
        # one doorbell-batched plane round for tuple + version
        got, ver = eng.read_rows_many(ec, (store["data"], store["ver"]), st["keys"])
        st["rvals"] = jnp.where(won[:, :, None], got, st["rvals"])
        st["ver_seen"] = jnp.where(won, ver, st["ver_seen"])

        lost = contenders & ~won
        if wait_die:
            lh, ll = eng.read_rows_many(ec, (store["lock_hi"], store["lock_lo"]), st["keys"])
            lock = TS(lh, ll)
            me = TS(st["ts_hi"][:, None], st["ts_lo"][:, None])
            older = ts_lt(me, lock) | ts_is_zero(lock)  # free again next tick -> wait
            abort_now = in_l & (lost & ~older).any(1)
        else:
            abort_now = in_l & lost.any(1)
        return StageOut(
            st,
            store,
            fail=abort_now,
            served_acc=acc,
            outstanding=st["valid"] & ~st["locked"],
        )

    return effect


def _specs(wait_die: bool):
    # reverse pipeline order: a txn advances at most one stage per tick
    return (
        StageSpec(
            stage=S_COMMIT,
            canon=ST_COMMIT,
            ops=rounds.ops_valid,  # RO ops still round-trip to release locks
            effect=rounds.writeback_commit_effect(),
            done="commit",
            salt_off=1,
            fuse_absorbs=ST_LOG,
        ),
        StageSpec(
            stage=S_ABREL,
            canon=ST_RELEASE,
            ops=rounds.ops_locked,
            effect=rounds.release_effect,
            done="abort",
            # retry same txn; WAITDIE keeps its original timestamp (die rule)
            next_stage=S_LOCK,
            salt_off=2,
        ),
        StageSpec(stage=S_LOG, canon=ST_LOG, kind=rounds.LOG, next_stage=S_COMMIT),
        StageSpec(
            stage=S_EXEC,
            canon=ST_EXEC,
            kind=rounds.EXEC,
            next_stage=S_LOG,
            fuse_next=S_COMMIT,
        ),
        StageSpec(
            stage=S_LOCK,
            canon=ST_LOCK,
            ops=rounds.ops_lock_pending(write_only=False),
            effect=_lock_effect(wait_die),
            next_stage=S_EXEC,
            start_exec=True,
            retry_stage=S_LOCK,
            abrel_stage=S_ABREL,
            salt_off=3,
        ),
    )


def make_tick(wait_die: bool):
    return rounds.make_tick(specs=_specs(wait_die), start_stage=S_LOCK, salt_mult=17)


STAGES_USED = ("lock", "log", "commit", "release")

# NOWAIT and WAITDIE are registry variants of this one module: same stage
# table, same effect hooks, one explicit conflict-rule flag.  (nowait.py /
# waitdie.py remain as import shims only.)
NOWAIT = registry.register_protocol(
    "nowait",
    tick=make_tick(wait_die=False),
    stages=STAGES_USED,
    capabilities=registry.Caps(),
    variant={"wait_die": False},
    family="twopl",
)
WAITDIE = registry.register_protocol(
    "waitdie",
    tick=make_tick(wait_die=True),
    stages=STAGES_USED,
    capabilities=registry.Caps(),
    variant={"wait_die": True},
    family="twopl",
)
