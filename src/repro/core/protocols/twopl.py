"""Shared 2PL machinery: NOWAIT and WAITDIE (paper §4.2, §4.3).

Stage machine:
  LOCK -> EXEC -> LOG -> COMMIT -> (done, regen)
    \\-> ABREL (release partial locks) -> retry same txn

NOWAIT: any lock conflict aborts immediately.
WAITDIE: on conflict, compare timestamps with the holder — strictly older
requesters WAIT (RPC: parked on the owner's wait-list, no re-issued rounds;
one-sided: re-post CAS+READ every round, consuming NIC capacity — exactly
the paper's §4.3 asymmetry), younger requesters DIE (abort, retry with the
ORIGINAL timestamp so they eventually age to the front).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core.costmodel import (
    ONE_SIDED,
    RPC,
    ST_COMMIT,
    ST_EXEC,
    ST_LOCK,
    ST_LOG,
    ST_RELEASE,
    CostModel,
)
from repro.core.engine import EngineConfig, Workload
from repro.core.store import owner_of
from repro.core.timestamps import TS, ts_eq, ts_is_zero, ts_lt

S_LOCK, S_EXEC, S_LOG, S_COMMIT, S_ABREL = range(5)

_CANON = (ST_LOCK, ST_EXEC, ST_LOG, ST_COMMIT, ST_RELEASE)


def canon_stage(st):
    """Map protocol stage -> canonical cost stage."""
    s = st["stage"]
    canon = jnp.full_like(s, -1)
    for proto_stage, c in enumerate(_CANON):
        canon = jnp.where(s == proto_stage, c, canon)
    return canon


def _apply_commit(ec: EngineConfig, store: Dict, st: Dict, eff) -> Dict:
    """Write back + unlock for served commit ops."""
    keys_f = st["keys"].reshape(-1)
    w_eff = (eff & st["is_w"]).reshape(-1)
    idx_w = jnp.where(w_eff, keys_f, ec.n_records)
    store = dict(store)
    store["data"] = store["data"].at[idx_w].set(
        st["wvals"].reshape(-1, st["wvals"].shape[-1]), mode="drop"
    )
    store["ver"] = store["ver"].at[idx_w].add(1, mode="drop")
    rel = (eff & st["locked"]).reshape(-1)
    idx_r = jnp.where(rel, keys_f, ec.n_records)
    store["lock_hi"] = store["lock_hi"].at[idx_r].set(0, mode="drop")
    store["lock_lo"] = store["lock_lo"].at[idx_r].set(0, mode="drop")
    return store


def make_tick(wait_die: bool):
    def tick(ec: EngineConfig, cm: CostModel, wl: Workload, st: Dict, store: Dict, t):
        salt = t * 17
        # ---- fresh slots -------------------------------------------------
        fresh = st["stage"] < 0
        st = eng.regen_txns(ec, wl, st, fresh, new_ts=True)
        st = dict(st)
        st["stage"] = jnp.where(fresh, S_LOCK, st["stage"])
        st = eng.base_time(ec, cm, st, canon_stage(st))

        # ---- COMMIT rounds (apply before lock arbitration: release first) -
        prim_c = ec.hybrid[ST_COMMIT]
        in_c = st["stage"] == S_COMMIT
        want = in_c[:, None] & st["valid"] & ~st["served"]
        served, load = eng.service_ops(ec, cm, st, want, prim_c == RPC, salt + 1)
        store = _apply_commit(ec, store, st, served)
        st["locked"] = st["locked"] & ~served
        st = eng.account_round(
            ec, cm, st, ST_COMMIT, served, load, prim_c, 8.0 + 4.0 * wl.rw, n_verbs=2
        )
        st = dict(st)
        st["served"] = st["served"] | served
        done_c = in_c & ~(st["valid"] & ~st["served"]).any(1)
        st = eng.finish_commit(ec, cm, st, done_c)
        st["stage"] = jnp.where(done_c, -1, st["stage"])
        st["served"] = jnp.where(done_c[:, None], False, st["served"])

        # ---- ABORT-RELEASE rounds ----------------------------------------
        prim_r = ec.hybrid[ST_RELEASE]
        in_a = st["stage"] == S_ABREL
        want = in_a[:, None] & st["locked"] & ~st["served"]
        served, load = eng.service_ops(ec, cm, st, want, prim_r == RPC, salt + 2)
        store = eng.release_locks(ec, store, st, served)
        st["locked"] = st["locked"] & ~served
        st = eng.account_round(ec, cm, st, ST_RELEASE, served, load, prim_r, 8.0)
        st = dict(st)
        st["served"] = st["served"] | served
        done_a = in_a & ~st["locked"].any(1)
        st = eng.finish_abort(st, done_a)
        # retry same txn; WAITDIE keeps its original timestamp (die rule)
        st["stage"] = jnp.where(done_a, S_LOCK, st["stage"])
        st["served"] = jnp.where(done_a[:, None], False, st["served"])
        st["lat_us"] = jnp.where(done_a, 0.0, st["lat_us"])
        st["rounds"] = jnp.where(done_a, 0, st["rounds"])

        # ---- LOG (coordinator log to n_backups, 1 round) --------------------
        prim_g = ec.hybrid[ST_LOG]
        in_g = st["stage"] == S_LOG
        log_bytes = (4.0 * wl.rw + 8.0) * cm.n_backups
        ops_g = in_g[:, None] & st["is_w"] & st["valid"]
        load_g = jnp.full(ops_g.shape, float(cm.n_backups), jnp.float32)
        st = eng.account_round(ec, cm, st, ST_LOG, ops_g, load_g, prim_g, log_bytes)
        # read-only txns skip logging cost (no ops) but still advance
        st["stage"] = jnp.where(in_g, S_COMMIT, st["stage"])
        st["served"] = jnp.where(in_g[:, None], False, st["served"])
        # ---- EXEC ----------------------------------------------------------
        in_e = st["stage"] == S_EXEC
        st["exec_left"] = jnp.where(in_e, jnp.maximum(st["exec_left"] - 1, 0), st["exec_left"])
        done_e = in_e & (st["exec_left"] == 0)
        wv = jax.vmap(wl.execute)(st["keys"], st["is_w"], st["valid"], st["rvals"])
        st["wvals"] = jnp.where(done_e[:, None, None], wv, st["wvals"])
        st["stage"] = jnp.where(done_e, S_LOG, st["stage"])

        # ---- LOCK rounds ---------------------------------------------------
        # RPC waiters are parked server-side (st["served"] marks delivered);
        # one-sided waiters re-post CAS+READ every tick.  prim_l may be a
        # traced scalar (batched sweep), so both planes run the same ops and
        # the plane-specific bookkeeping is selected with jnp.where: under a
        # parked RPC waiter st["served"] stays set, while the one-sided plane
        # never accumulates it — `want` is then pend again every tick.
        prim_l = ec.hybrid[ST_LOCK]
        is_rpc_l = jnp.asarray(prim_l == RPC)
        in_l = st["stage"] == S_LOCK
        pend = in_l[:, None] & st["valid"] & ~st["locked"]
        want = pend & ~st["served"]
        served, load = eng.service_ops(ec, cm, st, want, is_rpc_l, salt + 3)
        st = eng.account_round(
            ec, cm, st, ST_LOCK, served, load, prim_l, 16.0 + 4.0 * wl.rw, n_verbs=2
        )
        st = dict(st)
        st["served"] = st["served"] | (served & is_rpc_l)
        contenders = jnp.where(is_rpc_l, pend & st["served"], served)

        if wait_die:
            prio_hi = jnp.broadcast_to(st["ts_hi"][:, None], contenders.shape)
            prio_lo = jnp.broadcast_to(st["ts_lo"][:, None], contenders.shape)
        else:
            # hashed priority models arrival order; the UNIQUE index as the
            # lo word guarantees exactly one arbitration winner per key
            # (hash collisions would otherwise break lock exclusivity)
            base = jnp.arange(contenders.size, dtype=jnp.int32).reshape(contenders.shape)
            prio_hi = eng.hash_prio(base + st["ts_lo"][:, None], salt + 4)
            prio_lo = base
        won, store = eng.try_lock(ec, store, st, contenders, prio_hi, prio_lo)
        st["locked"] = st["locked"] | won
        # fetch records under freshly-won locks (CAS+READ / handler reply)
        got = eng.gather_rows(store["data"], st["keys"])
        st["rvals"] = jnp.where(won[:, :, None], got, st["rvals"])
        st["ver_seen"] = jnp.where(won, eng.gather_rows(store["ver"], st["keys"]), st["ver_seen"])

        lost = contenders & ~won
        if wait_die:
            lock = TS(
                eng.gather_rows(store["lock_hi"], st["keys"]),
                eng.gather_rows(store["lock_lo"], st["keys"]),
            )
            me = TS(st["ts_hi"][:, None], st["ts_lo"][:, None])
            older = ts_lt(me, lock) | ts_is_zero(lock)  # free again next tick -> wait
            must_die = (lost & ~older).any(1)
            abort_now = in_l & must_die
        else:
            abort_now = in_l & lost.any(1)

        locked_all = in_l & ~(st["valid"] & ~st["locked"]).any(1)
        go_exec = locked_all & ~abort_now
        st["stage"] = jnp.where(go_exec, S_EXEC, st["stage"])
        st["exec_left"] = jnp.where(go_exec, wl.exec_ticks, st["exec_left"])
        st["served"] = jnp.where(go_exec[:, None], False, st["served"])
        has_locks = st["locked"].any(1)
        st["stage"] = jnp.where(abort_now & has_locks, S_ABREL, st["stage"])
        st["served"] = jnp.where(abort_now[:, None], False, st["served"])
        # no locks held -> abort immediately without a release round
        insta = abort_now & ~has_locks
        st = eng.finish_abort(st, insta)
        st["lat_us"] = jnp.where(insta, 0.0, st["lat_us"])
        st["rounds"] = jnp.where(insta, 0, st["rounds"])

        return st, store

    return tick
