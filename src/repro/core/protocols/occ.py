"""OCC (paper §4.1, DrTM+H layout: lock | seq | record).

FETCH (speculative, no locks) -> EXEC -> LOCK(WS) -> VALIDATE(RS seq
unchanged, unlocked) -> LOG -> COMMIT(write back, seq+1, unlock).
Any lock failure or validation failure aborts (release WS locks, retry).
Declared as a rounds.StageSpec table; only the effect hooks below are
OCC-specific.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import registry
from repro.core import rounds
from repro.core.costmodel import (
    ST_COMMIT,
    ST_EXEC,
    ST_FETCH,
    ST_LOCK,
    ST_LOG,
    ST_RELEASE,
    ST_VALIDATE,
)
from repro.core.rounds import StageOut, StageSpec
from repro.core.timestamps import TS, ts_eq, ts_is_zero

S_FETCH, S_EXEC, S_LOCKW, S_VALID, S_LOG, S_COMMIT, S_ABREL = range(7)


def _validate_effect(ec, cm, wl, st, store, in_v, served, salt):
    """Re-read RS seq words: unchanged + unlocked (or locked by me)."""
    st = dict(st)
    seq_now, lh, ll = eng.read_rows_many(
        ec, (store["seq"], store["lock_hi"], store["lock_lo"]), st["keys"]
    )
    lock = TS(lh, ll)
    mine = ts_eq(lock, TS(st["ts_hi"][:, None], st["ts_lo"][:, None]))
    bad = served & ((seq_now != st["seq_seen"]) | (~ts_is_zero(lock) & ~mine))
    return StageOut(st, store, fail=in_v & bad.any(1))


def _lock_effect(ec, cm, wl, st, store, in_l, served, salt):
    """CAS the write-set locks; DrTM+H folds a seq re-check into the
    lock+read doorbell."""
    st = dict(st)
    base = eng.op_index(ec, served.shape[1])
    # unique logical-op lo word => exactly one winner per key (twopl.py note)
    won, store = eng.try_lock(
        ec, store, st, served, eng.hash_prio(base + st["ts_lo"][:, None], salt + 1), base
    )
    st["locked"] = st["locked"] | won
    lost = served & ~won
    seq_now = eng.read_rows(ec, store["seq"], st["keys"])
    ws_changed = (won & (seq_now != st["seq_seen"])).any(1)
    ws = st["valid"] & st["is_w"]
    return StageOut(
        st,
        store,
        fail=in_l & (lost.any(1) | ws_changed),
        served_acc=jnp.zeros_like(served),  # one-sided waiters re-post
        outstanding=ws & ~st["locked"],
    )


def _fetch_effect(ec, cm, wl, st, store, in_f, served, salt):
    """Speculative tuple+seq read (no locks taken): one batched plane round."""
    st = dict(st)
    got, seq, ver = eng.read_rows_many(
        ec, (store["data"], store["seq"], store["ver"]), st["keys"]
    )
    st["rvals"] = jnp.where(served[:, :, None], got, st["rvals"])
    st["seq_seen"] = jnp.where(served, seq, st["seq_seen"])
    st["ver_seen"] = jnp.where(served, ver, st["ver_seen"])
    return StageOut(st, store)


SPECS = (
    StageSpec(
        stage=S_COMMIT,
        canon=ST_COMMIT,
        ops=rounds.ops_write_set,
        effect=rounds.writeback_commit_effect(bump_seq=True),
        done="commit",
        salt_off=1,
        fuse_absorbs=ST_LOG,
    ),
    StageSpec(
        stage=S_ABREL,
        canon=ST_RELEASE,
        ops=rounds.ops_locked,
        effect=rounds.release_effect,
        done="abort",
        next_stage=S_FETCH,
        salt_off=2,
    ),
    StageSpec(stage=S_LOG, canon=ST_LOG, kind=rounds.LOG, next_stage=S_COMMIT),
    StageSpec(
        stage=S_VALID,
        canon=ST_VALIDATE,
        ops=rounds.ops_read_set,
        effect=_validate_effect,
        next_stage=S_LOG,
        fuse_next=S_COMMIT,
        # write-heavy OCC's VALIDATE→LOG merge-table pair (rounds.MERGE_TABLE):
        # with both stages one-sided, the log WRITEs ride the validation
        # doorbell — a validating txn with writes skips the LOG round entirely
        fuse_absorbs=ST_LOG,
        retry_stage=S_FETCH,
        abrel_stage=S_ABREL,
        salt_off=3,
    ),
    StageSpec(
        stage=S_LOCKW,
        canon=ST_LOCK,
        ops=rounds.ops_lock_pending(write_only=True),
        effect=_lock_effect,
        next_stage=S_VALID,  # no writes at all -> straight to validate
        retry_stage=S_FETCH,
        abrel_stage=S_ABREL,
        salt_off=4,
    ),
    StageSpec(stage=S_EXEC, canon=ST_EXEC, kind=rounds.EXEC, next_stage=S_LOCKW),
    StageSpec(
        stage=S_FETCH,
        canon=ST_FETCH,
        ops=rounds.ops_valid,
        effect=_fetch_effect,
        next_stage=S_EXEC,
        start_exec=True,
        salt_off=6,
    ),
)

tick = rounds.make_tick(specs=SPECS, start_stage=S_FETCH, salt_mult=29)

STAGES_USED = ("fetch", "lock", "validate", "log", "commit", "release")

registry.register_protocol("occ", tick=tick, stages=STAGES_USED, capabilities=registry.Caps())
