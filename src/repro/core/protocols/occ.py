"""OCC (paper §4.1, DrTM+H layout: lock | seq | record).

FETCH (speculative, no locks) -> EXEC -> LOCK(WS) -> VALIDATE(RS seq
unchanged, unlocked) -> LOG -> COMMIT(write back, seq+1, unlock).
Any lock failure or validation failure aborts (release WS locks, retry).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core.costmodel import (
    ONE_SIDED,
    RPC,
    ST_COMMIT,
    ST_EXEC,
    ST_FETCH,
    ST_LOCK,
    ST_LOG,
    ST_RELEASE,
    ST_VALIDATE,
    CostModel,
)
from repro.core.engine import EngineConfig, Workload
from repro.core.timestamps import TS, ts_eq, ts_is_zero

S_FETCH, S_EXEC, S_LOCKW, S_VALID, S_LOG, S_COMMIT, S_ABREL = range(7)
_CANON = (ST_FETCH, ST_EXEC, ST_LOCK, ST_VALIDATE, ST_LOG, ST_COMMIT, ST_RELEASE)


def canon_stage(st):
    s = st["stage"]
    canon = jnp.full_like(s, -1)
    for ps, c in enumerate(_CANON):
        canon = jnp.where(s == ps, c, canon)
    return canon


def _apply_commit(ec: EngineConfig, store: Dict, st: Dict, eff) -> Dict:
    keys_f = st["keys"].reshape(-1)
    w_eff = (eff & st["is_w"]).reshape(-1)
    idx_w = jnp.where(w_eff, keys_f, ec.n_records)
    store = dict(store)
    store["data"] = store["data"].at[idx_w].set(
        st["wvals"].reshape(-1, st["wvals"].shape[-1]), mode="drop"
    )
    store["ver"] = store["ver"].at[idx_w].add(1, mode="drop")
    store["seq"] = store["seq"].at[idx_w].add(1, mode="drop")
    rel = (eff & st["locked"]).reshape(-1)
    idx_r = jnp.where(rel, keys_f, ec.n_records)
    store["lock_hi"] = store["lock_hi"].at[idx_r].set(0, mode="drop")
    store["lock_lo"] = store["lock_lo"].at[idx_r].set(0, mode="drop")
    return store


def _abort_to_retry(st, fail_mask, retry_stage):
    """Route failing txns to ABREL (if holding locks) or straight to retry."""
    has_locks = st["locked"].any(1)
    st = dict(st)
    st["stage"] = jnp.where(fail_mask, jnp.where(has_locks, S_ABREL, retry_stage), st["stage"])
    insta = fail_mask & ~has_locks
    st = eng.finish_abort(st, insta)
    st["lat_us"] = jnp.where(insta, 0.0, st["lat_us"])
    st["rounds"] = jnp.where(insta, 0, st["rounds"])
    return st


def tick(ec: EngineConfig, cm: CostModel, wl: Workload, st: Dict, store: Dict, t):
    salt = t * 29
    # ---- fresh ------------------------------------------------------------
    fresh = st["stage"] < 0
    st = eng.regen_txns(ec, wl, st, fresh, new_ts=True)
    st = dict(st)
    st["stage"] = jnp.where(fresh, S_FETCH, st["stage"])
    st = eng.base_time(ec, cm, st, canon_stage(st))

    # ---- COMMIT ------------------------------------------------------------
    prim_c = ec.hybrid[ST_COMMIT]
    in_c = st["stage"] == S_COMMIT
    ws = st["valid"] & st["is_w"]
    want = in_c[:, None] & ws & ~st["served"]
    served, load = eng.service_ops(ec, cm, st, want, prim_c == RPC, salt + 1)
    store = _apply_commit(ec, store, st, served)
    st["locked"] = st["locked"] & ~served
    st = eng.account_round(ec, cm, st, ST_COMMIT, served, load, prim_c, 12.0 + 4.0 * wl.rw, n_verbs=2)
    st = dict(st)
    st["served"] = st["served"] | served
    done_c = in_c & ~(ws & ~st["served"]).any(1)
    st = eng.finish_commit(ec, cm, st, done_c)
    st["stage"] = jnp.where(done_c, -1, st["stage"])
    st["served"] = jnp.where(done_c[:, None], False, st["served"])

    # ---- ABORT-RELEASE -------------------------------------------------------
    prim_r = ec.hybrid[ST_RELEASE]
    in_a = st["stage"] == S_ABREL
    want = in_a[:, None] & st["locked"] & ~st["served"]
    served, load = eng.service_ops(ec, cm, st, want, prim_r == RPC, salt + 2)
    store = eng.release_locks(ec, store, st, served)
    st["locked"] = st["locked"] & ~served
    st = eng.account_round(ec, cm, st, ST_RELEASE, served, load, prim_r, 8.0)
    st = dict(st)
    st["served"] = st["served"] | served
    done_a = in_a & ~st["locked"].any(1)
    st = eng.finish_abort(st, done_a)
    st["stage"] = jnp.where(done_a, S_FETCH, st["stage"])
    st["served"] = jnp.where(done_a[:, None], False, st["served"])
    st["lat_us"] = jnp.where(done_a, 0.0, st["lat_us"])
    st["rounds"] = jnp.where(done_a, 0, st["rounds"])

    # ---- LOG -----------------------------------------------------------------
    prim_g = ec.hybrid[ST_LOG]
    in_g = st["stage"] == S_LOG
    ops_g = in_g[:, None] & st["is_w"] & st["valid"]
    load_g = jnp.full(ops_g.shape, float(cm.n_backups), jnp.float32)
    st = eng.account_round(ec, cm, st, ST_LOG, ops_g, load_g, prim_g, (4.0 * wl.rw + 8.0) * cm.n_backups)
    st["stage"] = jnp.where(in_g, S_COMMIT, st["stage"])
    st["served"] = jnp.where(in_g[:, None], False, st["served"])

    # ---- VALIDATE (re-read RS seq; unchanged + unlocked) -----------------------
    prim_v = ec.hybrid[ST_VALIDATE]
    in_v = st["stage"] == S_VALID
    rs = st["valid"] & ~st["is_w"]
    want = in_v[:, None] & rs & ~st["served"]
    served, load = eng.service_ops(ec, cm, st, want, prim_v == RPC, salt + 3)
    st = eng.account_round(ec, cm, st, ST_VALIDATE, served, load, prim_v, 12.0)
    st = dict(st)
    seq_now = eng.gather_rows(store["seq"], st["keys"])
    lock = TS(eng.gather_rows(store["lock_hi"], st["keys"]), eng.gather_rows(store["lock_lo"], st["keys"]))
    mine = ts_eq(lock, TS(st["ts_hi"][:, None], st["ts_lo"][:, None]))
    bad = served & ((seq_now != st["seq_seen"]) | (~ts_is_zero(lock) & ~mine))
    st["served"] = st["served"] | served
    fail_v = in_v & bad.any(1)
    done_v = in_v & ~(rs & ~st["served"]).any(1) & ~fail_v
    st = _abort_to_retry(st, fail_v, S_FETCH)
    st["stage"] = jnp.where(done_v, S_LOG, st["stage"])
    st["served"] = jnp.where((done_v | fail_v)[:, None], False, st["served"])

    # ---- LOCK WS ----------------------------------------------------------------
    prim_l = ec.hybrid[ST_LOCK]
    in_l = st["stage"] == S_LOCKW
    ws = st["valid"] & st["is_w"]
    pend = in_l[:, None] & ws & ~st["locked"]
    served, load = eng.service_ops(ec, cm, st, pend, prim_l == RPC, salt + 4)
    st = eng.account_round(ec, cm, st, ST_LOCK, served, load, prim_l, 16.0, n_verbs=2)
    st = dict(st)
    base = jnp.arange(pend.size, dtype=jnp.int32).reshape(pend.shape)
    # unique lo word => exactly one winner per key (see twopl.py note)
    won, store = eng.try_lock(
        ec, store, st, served, eng.hash_prio(base + st["ts_lo"][:, None], salt + 5), base
    )
    st["locked"] = st["locked"] | won
    lost = served & ~won
    # DrTM+H folds a seq re-check into the lock+read doorbell
    seq_now = eng.gather_rows(store["seq"], st["keys"])
    ws_changed = (won & (seq_now != st["seq_seen"])).any(1)
    fail_l = in_l & (lost.any(1) | ws_changed)
    locked_all = in_l & ~(ws & ~st["locked"]).any(1) & ~fail_l
    # no writes at all -> skip straight to validate
    st = _abort_to_retry(st, fail_l, S_FETCH)
    st["stage"] = jnp.where(locked_all, S_VALID, st["stage"])
    st["served"] = jnp.where((locked_all | fail_l)[:, None], False, st["served"])

    # ---- EXEC ----------------------------------------------------------------
    in_e = st["stage"] == S_EXEC
    st["exec_left"] = jnp.where(in_e, jnp.maximum(st["exec_left"] - 1, 0), st["exec_left"])
    done_e = in_e & (st["exec_left"] == 0)
    wv = jax.vmap(wl.execute)(st["keys"], st["is_w"], st["valid"], st["rvals"])
    st["wvals"] = jnp.where(done_e[:, None, None], wv, st["wvals"])
    st["stage"] = jnp.where(done_e, S_LOCKW, st["stage"])

    # ---- FETCH (speculative tuple+seq read) -------------------------------------
    prim_f = ec.hybrid[ST_FETCH]
    in_f = st["stage"] == S_FETCH
    want = in_f[:, None] & st["valid"] & ~st["served"]
    served, load = eng.service_ops(ec, cm, st, want, prim_f == RPC, salt + 6)
    st = eng.account_round(ec, cm, st, ST_FETCH, served, load, prim_f, 12.0 + 4.0 * wl.rw)
    st = dict(st)
    got = eng.gather_rows(store["data"], st["keys"])
    st["rvals"] = jnp.where(served[:, :, None], got, st["rvals"])
    st["seq_seen"] = jnp.where(served, eng.gather_rows(store["seq"], st["keys"]), st["seq_seen"])
    st["ver_seen"] = jnp.where(served, eng.gather_rows(store["ver"], st["keys"]), st["ver_seen"])
    st["served"] = st["served"] | served
    done_f = in_f & ~(st["valid"] & ~st["served"]).any(1)
    st["stage"] = jnp.where(done_f, S_EXEC, st["stage"])
    st["exec_left"] = jnp.where(done_f, wl.exec_ticks, st["exec_left"])
    st["served"] = jnp.where(done_f[:, None], False, st["served"])
    return st, store


STAGES_USED = ("fetch", "lock", "validate", "log", "commit", "release")
