"""WAITDIE (paper §4.3): registry variant of twopl (older waits, younger dies).

Import shim only — the protocol itself is registered by
``repro.core.protocols.twopl`` as ``register_protocol("waitdie",
variant={"wait_die": True})``.
"""
from repro.core.protocols.twopl import WAITDIE as _entry
from repro.core.protocols.twopl import STAGES_USED  # noqa: F401

tick = _entry.tick
