"""WAITDIE (paper §4.3): 2PL; older waits, younger dies (original ts kept)."""
from repro.core.protocols.twopl import make_tick

tick = make_tick(wait_die=True)
STAGES_USED = ("lock", "log", "commit", "release")
