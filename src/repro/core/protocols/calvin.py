"""CALVIN (paper §4.6): deterministic, epoch-based, shared-nothing.

Per epoch: (1) sequencing layer — every node broadcasts its local batch of
transactions to all other nodes (RPC batch, or one-sided: two doorbell-
batched WRITEs into pre-agreed per-(epoch, sender) ring buffers — value
then valid-flag); (2) RS/WS forwarding — passive participants send RS
records to active participants, actives exchange WS records; (3) local
deterministic execution in the agreed global order (lock-free: conflicting
transactions execute in dependency waves).  No aborts by construction.

Epoch synchronization is why co-routines do not help CALVIN (paper Fig. 7):
the epoch barrier serializes sequencer rounds regardless of overlap.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core import costmodel as cmod
from repro.core import engine as eng
from repro.core import registry
from repro.core.costmodel import ONE_SIDED, RPC, CostModel
from repro.core.engine import EngineConfig, Workload
from repro.core.store import init_store

tick = None  # CALVIN uses the epoch runner below, not the slot engine
STAGES_USED = ("sequence", "forward", "execute")


def _epoch_txns(ec: EngineConfig, wl: Workload, epoch, key0):
    """Generate this epoch's global batch in deterministic order.

    Identity flows through LOGICAL slot ids and generated keys are remapped
    onto the padded store layout, so bucket-padded runs (sweep.py) stay
    bitwise-equal to unpadded ones; dead (padded) slots get valid=False.
    """
    lsid, node, alive = eng.logical_ids(ec)

    def gen_one(s, n):
        k = jax.random.fold_in(jax.random.fold_in(key0, s), epoch)
        return wl.gen(k, n, s)

    keys, is_w, valid = jax.vmap(gen_one)(lsid, node)
    keys = eng.physical_keys(ec, keys)
    if alive is not None:
        valid = valid & alive[:, None]
    return keys, is_w, valid, node


def _waves(ec: EngineConfig, keys, is_w, valid):
    """Dependency wave per txn: readers wait for earlier writers; writers
    wait for all earlier accesses (deterministic lock schedule)."""
    N, K = keys.shape
    M = N * K
    kf = keys.reshape(-1)
    order = jnp.repeat(jnp.arange(N, dtype=jnp.int32), K)
    wf = (is_w & valid).reshape(-1)
    af = valid.reshape(-1)
    sort_key = jnp.where(af, kf * (M + 1) + order, jnp.int32(2**30))
    perm = jnp.argsort(sort_key)
    k_s = kf[perm]
    w_s = wf[perm].astype(jnp.int32)
    a_s = af[perm].astype(jnp.int32)
    first = jnp.concatenate([jnp.ones(1, bool), k_s[1:] != k_s[:-1]])
    # exclusive prefix counts within key segments
    cw = jnp.cumsum(w_s) - w_s
    ca = jnp.cumsum(a_s) - a_s
    seg_cw0 = jnp.where(first, cw, 0)
    seg_ca0 = jnp.where(first, ca, 0)
    seg_cw0 = jax.lax.associative_scan(jnp.maximum, seg_cw0)
    seg_ca0 = jax.lax.associative_scan(jnp.maximum, seg_ca0)
    earlier_writers = cw - seg_cw0
    earlier_access = ca - seg_ca0
    wave_s = jnp.where(w_s > 0, earlier_access, earlier_writers)
    wave_f = jnp.zeros(M, jnp.int32).at[perm].set(wave_s.astype(jnp.int32))
    wave_f = jnp.where(af, wave_f, 0)
    return wave_f.reshape(N, K).max(1)  # txn wave


def run_epochs(
    ec: EngineConfig, cm: CostModel, wl: Workload, n_epochs: int, *, epochs_active=None
):
    """Returns metrics matching engine.summarize's schema.

    ``epochs_active`` (traced, None = unpadded) is the tick-bucketing mask:
    epochs past it execute zero waves, freeze the store, and contribute
    zero to every stat, so a padded run is bitwise-equal to a run of
    exactly ``epochs_active`` epochs.  When ``ec.shard`` is set the store
    lives node-sharded and the wave executor's gathers/scatters route
    through the plane primitives (one collective per wave round).
    """
    key0 = jax.random.PRNGKey(ec.seed)
    store = init_store("nowait", ec.records_local, wl.rw, wl.init_value)
    # traceable under the batched sweep: no Python branching on the plane
    one_sided = jnp.asarray(ec.hybrid[0] == ONE_SIDED)
    is_rpc = jnp.logical_not(one_sided)
    K = wl.max_ops
    # live co-routines per node / batch size under bucket padding (traced)
    act_c = ec.coroutines if ec.active_coroutines is None else ec.active_coroutines
    n_live = jnp.asarray(ec.n_nodes * act_c, jnp.int32)

    def epoch_body(carry, epoch):
        store, = carry
        live = (
            jnp.asarray(True)
            if epochs_active is None
            else epoch < jnp.asarray(epochs_active, jnp.int32)
        )
        keys, is_w, valid, node = _epoch_txns(ec, wl, epoch, key0)
        wave = _waves(ec, keys, is_w, valid)
        n_waves = jnp.where(live, wave.max() + 1, 0)

        # ---- execute waves sequentially (deterministic order) ----------
        def wave_body(w, sd):
            rvals = eng.read_rows(ec, sd["data"], keys)
            wv = jax.vmap(wl.execute)(keys, is_w, valid, rvals)
            active = (wave == w)[:, None] & is_w & valid
            af = active.reshape(-1)
            idx = jnp.where(af, keys.reshape(-1), ec.n_records)
            sd = dict(sd)
            sd["data"] = eng.write_rows(ec, sd["data"], idx, wv.reshape(-1, wl.rw))
            sd["ver"] = eng.write_rows(ec, sd["ver"], idx, 1, op="add")
            return sd

        store = jax.lax.fori_loop(0, n_waves, wave_body, store)

        # ---- epoch cost model -------------------------------------------
        # sequencing: each node ships its C txn descriptors to n-1 peers
        # (message shapes from the central wire-cost table, DESIGN.md §5)
        desc_bytes = act_c * cmod.CALVIN_WIRE["sequence"].bytes_for(wl.rw, n_ops=K)
        # n_verbs=2 models the one-sided value+valid-flag WRITE pair; the RPC
        # branch of round_latency_us never reads n_verbs, so passing 2
        # unconditionally keeps the expression traceable.
        bcast = cmod.round_latency_us(
            cm, is_rpc, float(ec.n_nodes - 1), desc_bytes * (ec.n_nodes - 1),
            n_verbs=2, doorbell=ec.doorbell,
        )
        # RS/WS forwarding: ops whose owner differs from an active participant
        owner = keys // ec.records_per_node
        remote = valid & (owner != node[:, None])
        fwd_ops = remote.sum()
        fwd_bytes = fwd_ops * cmod.CALVIN_WIRE["forward"].bytes_for(wl.rw)
        fwd = cmod.round_latency_us(
            cm, is_rpc, fwd_ops / max(ec.n_nodes, 1), fwd_bytes / max(ec.n_nodes, 1),
            n_verbs=2, doorbell=ec.doorbell,
        )
        exec_us = n_waves.astype(jnp.float32) * wl.exec_ticks * cm.tick_us
        barrier = cm.tick_us  # epoch sync barrier across sequencers
        epoch_us = bcast + fwd + exec_us + barrier
        stats = {
            "commits": jnp.where(live, n_live, 0),
            "epoch_us": jnp.where(live, epoch_us, 0.0),
            "rounds": jnp.where(
                live, jnp.where(one_sided, jnp.float32(4), jnp.float32(2)), 0.0
            ),
            "waves": n_waves,
        }
        return (store,), stats

    (store,), stats = jax.lax.scan(epoch_body, (store,), jnp.arange(n_epochs))
    n_eff = n_epochs if epochs_active is None else jnp.asarray(epochs_active, jnp.int32)
    total_us = stats["epoch_us"].sum()
    commits = stats["commits"].sum()
    metrics = {
        "commits": commits,
        "aborts": jnp.int32(0),
        "throughput_mtps": commits / total_us,
        # txns commit at epoch end; dead (padded) epochs contribute zero
        "avg_latency_us": stats["epoch_us"].sum() / n_eff,
        "abort_rate": jnp.float32(0.0),
        "avg_round_trips": stats["rounds"].sum() / n_eff,
        "avg_waves": stats["waves"].sum() / n_eff,
        "stage_us_per_commit": jnp.zeros((cmod.N_STAGES,), jnp.float32),
    }
    return store, metrics


def run_epochs_sharded(
    ec: EngineConfig,
    cm: CostModel,
    wl: Workload,
    n_epochs: int,
    *,
    devices=None,
    axis: str = "node",
    epochs_active=None,
):
    """:func:`run_epochs` SPMD on a ``node`` device mesh (DESIGN.md §7).

    CALVIN's shared-nothing layout maps directly: the partitioned store is
    sharded by owner, sequencing/forwarding cost is sequencer-replicated
    bookkeeping, and each dependency wave's record exchange is one plane
    round (read collective + owner-local writes).  Bitwise-equal commit
    counters vs the dense :func:`run_epochs`.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core import planes

    mesh, ec_sh = eng.node_mesh_config(ec, devices, axis)

    def body():
        return run_epochs(ec_sh, cm, wl, n_epochs, epochs_active=epochs_active)

    return planes.shard_map(
        body, mesh=mesh, in_specs=(), out_specs=(P(axis), P()), check_rep=False
    )()


# ---------------------------------------------------------------------------
# Registry entry: CALVIN is epoch-driven, so it owns its run hooks instead of
# a slot-engine tick.  ``ticks`` from the front door map onto epochs at the
# historical ratio (one epoch per 8 ticks, floor 8) so grid specs stay
# comparable across protocols.
# ---------------------------------------------------------------------------


def epochs_for_ticks(ticks: int) -> int:
    return max(int(ticks) // 8, 8)


def _grid_run(entry, ec, cm, wl, *, ticks, warmup, ticks_active):
    ep_act = (
        None
        if ticks_active is None
        else jnp.maximum(jnp.asarray(ticks_active, jnp.int32) // 8, 8)
    )
    _, m = run_epochs(ec, cm, wl, epochs_for_ticks(ticks), epochs_active=ep_act)
    return m


def _node_run(entry, ec, cm, wl, *, ticks, warmup, devices):
    _, m = run_epochs_sharded(ec, cm, wl, epochs_for_ticks(ticks), devices=devices)
    return m


registry.register_protocol(
    "calvin",
    tick=None,
    stages=STAGES_USED,
    hooks=registry.RunHooks(grid_run=_grid_run, node_run=_node_run),
    capabilities=registry.Caps(
        # the wave executor's per-config traced wave count cannot batch
        # around the node collectives: single-config node meshes only
        node_shardable=True,
        batch_node_shardable=False,
        deterministic=True,
        tick_driven=False,
    ),
)
