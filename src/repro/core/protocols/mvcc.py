"""MVCC (paper §4.4): tts | rts | wts[4] | record[4].

Read (RS): atomic double-read of the tuple; Cond R1 — a committed version
with the largest wts < ctts exists among the 4 static slots; Cond R2 — tts
is 0 or > ctts.  Abort if either fails (slot overflow shows up as R1
failure — the paper measured <=4.2% of read aborts from overflow at 4
slots).  Then bump rts to max(rts, ctts) via CAS-max (retry-until-success
is contention, not conflict).

Write (WS): read metadata, check Cond W1 (ctts > max wts and > rts), CAS
the lock (tts), then RE-CHECK W1 with the returned metadata — the paper's
*double-read/double-check* closing the W1/W2 atomicity gap.  Commit
overwrites the OLDEST wts slot + its record, then unlocks.

Local clocks advance to any larger observed wts/rts (drift limiter, §4.4).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core.costmodel import (
    ONE_SIDED,
    RPC,
    ST_COMMIT,
    ST_EXEC,
    ST_FETCH,
    ST_LOCK,
    ST_LOG,
    ST_RELEASE,
    ST_VALIDATE,
    CostModel,
)
from repro.core.engine import EngineConfig, Workload
from repro.core.timestamps import TS, ts_eq, ts_is_zero, ts_lt

S_READ, S_RTS, S_LOCKW, S_EXEC, S_LOG, S_COMMIT, S_ABREL = range(7)
_CANON = (ST_FETCH, ST_VALIDATE, ST_LOCK, ST_EXEC, ST_LOG, ST_COMMIT, ST_RELEASE)


def canon_stage(st):
    s = st["stage"]
    canon = jnp.full_like(s, -1)
    for ps, c in enumerate(_CANON):
        canon = jnp.where(s == ps, c, canon)
    return canon


def _vts(store, keys) -> TS:
    """Version timestamps at keys: (N,K,4) TS."""
    return TS(eng.gather_rows(store["wts_hi"], keys), eng.gather_rows(store["wts_lo"], keys))


def _lex_lt(ah, al, bh, bl):
    return (ah < bh) | ((ah == bh) & (al < bl))


def _best_version(wts: TS, ctts: TS):
    """Largest wts strictly < ctts among slots. Returns (found, slot_idx)."""
    ch, cl = ctts.hi[..., None], ctts.lo[..., None]
    cand = _lex_lt(wts.hi, wts.lo, ch, cl) & ~((wts.hi == 0) & (wts.lo == 0))
    bh = jnp.where(cand, wts.hi, jnp.int32(-(2**31)))
    bl = jnp.where(cand, wts.lo, jnp.int32(-(2**31)))
    best_h = bh.max(-1, keepdims=True)
    is_h = cand & (wts.hi == best_h)
    bl2 = jnp.where(is_h, bl, jnp.int32(-(2**31)))
    best_l = bl2.max(-1, keepdims=True)
    winner = is_h & (wts.lo == best_l)
    found = cand.any(-1)
    slot = jnp.argmax(winner, axis=-1)
    return found, slot.astype(jnp.int32)


def _max_wts(wts: TS) -> TS:
    bh = wts.hi.max(-1, keepdims=True)
    is_h = wts.hi == bh
    bl = jnp.where(is_h, wts.lo, jnp.int32(-(2**31))).max(-1)
    return TS(bh[..., 0], bl)


def _oldest_slot(wts: TS):
    bh = wts.hi.min(-1, keepdims=True)
    is_h = wts.hi == bh
    bl = jnp.where(is_h, wts.lo, jnp.int32(2**31 - 1)).min(-1, keepdims=True)
    winner = is_h & (wts.lo == bl)
    return jnp.argmax(winner, axis=-1).astype(jnp.int32)


def _check_w1(store, st, ops) -> jnp.ndarray:
    """Cond W1 per op: ctts > max(wts) and ctts > rts."""
    wts = _vts(store, st["keys"])
    mx = _max_wts(wts)
    rts = TS(eng.gather_rows(store["rts_hi"], st["keys"]), eng.gather_rows(store["rts_lo"], st["keys"]))
    me = TS(st["ts_hi"][:, None], st["ts_lo"][:, None])
    ok = _lex_lt(mx.hi, mx.lo, me.hi, me.lo) & _lex_lt(rts.hi, rts.lo, me.hi, me.lo)
    return ok | ~ops


def _abort_to_retry(st, fail_mask):
    has_locks = st["locked"].any(1)
    st = dict(st)
    st["stage"] = jnp.where(fail_mask, jnp.where(has_locks, S_ABREL, S_READ), st["stage"])
    insta = fail_mask & ~has_locks
    st = eng.finish_abort(st, insta)
    # MVCC retries take a fresh (larger) timestamp
    st["clock"] = jnp.where(insta, st["clock"] + 1, st["clock"])
    st["ts_hi"] = jnp.where(insta, st["clock"], st["ts_hi"])
    st["lat_us"] = jnp.where(insta, 0.0, st["lat_us"])
    st["rounds"] = jnp.where(insta, 0, st["rounds"])
    st["served"] = jnp.where(insta[:, None], False, st["served"])
    return st


def tick(ec: EngineConfig, cm: CostModel, wl: Workload, st: Dict, store: Dict, t):
    salt = t * 37
    fresh = st["stage"] < 0
    st = eng.regen_txns(ec, wl, st, fresh, new_ts=True)
    st = dict(st)
    st["stage"] = jnp.where(fresh, S_READ, st["stage"])
    st = eng.base_time(ec, cm, st, canon_stage(st))
    me = lambda: TS(st["ts_hi"][:, None], st["ts_lo"][:, None])

    # ---- COMMIT: write oldest slot + unlock ---------------------------------
    prim_c = ec.hybrid[ST_COMMIT]
    in_c = st["stage"] == S_COMMIT
    ws = st["valid"] & st["is_w"]
    want = in_c[:, None] & ws & ~st["served"]
    served, load = eng.service_ops(ec, cm, st, want, prim_c == RPC, salt + 1)
    wts = _vts(store, st["keys"])
    oldest = _oldest_slot(wts)  # (N,K)
    keys_f = st["keys"].reshape(-1)
    eff = served.reshape(-1)
    idx_k = jnp.where(eff, keys_f, ec.n_records)
    idx_s = oldest.reshape(-1)
    store = dict(store)
    new_ver = eng.gather_rows(store["ver"], st["keys"]) + 1
    store["wts_hi"] = store["wts_hi"].at[idx_k, idx_s].set(
        jnp.repeat(st["ts_hi"], st["keys"].shape[1]), mode="drop"
    )
    store["wts_lo"] = store["wts_lo"].at[idx_k, idx_s].set(
        jnp.repeat(st["ts_lo"], st["keys"].shape[1]), mode="drop"
    )
    store["vdata"] = store["vdata"].at[idx_k, idx_s].set(
        st["wvals"].reshape(-1, wl.rw), mode="drop"
    )
    store["vver"] = store["vver"].at[idx_k, idx_s].set(new_ver.reshape(-1), mode="drop")
    store["ver"] = store["ver"].at[idx_k].add(1, mode="drop")
    rel = (served & st["locked"]).reshape(-1)
    idx_r = jnp.where(rel, keys_f, ec.n_records)
    store["lock_hi"] = store["lock_hi"].at[idx_r].set(0, mode="drop")
    store["lock_lo"] = store["lock_lo"].at[idx_r].set(0, mode="drop")
    st["locked"] = st["locked"] & ~served
    st = eng.account_round(ec, cm, st, ST_COMMIT, served, load, prim_c, 16.0 + 4.0 * wl.rw, n_verbs=2)
    st = dict(st)
    st["served"] = st["served"] | served
    done_c = in_c & ~(ws & ~st["served"]).any(1)
    st = eng.finish_commit(ec, cm, st, done_c)
    st["stage"] = jnp.where(done_c, -1, st["stage"])
    st["served"] = jnp.where(done_c[:, None], False, st["served"])

    # ---- ABORT-RELEASE --------------------------------------------------------
    prim_r = ec.hybrid[ST_RELEASE]
    in_a = st["stage"] == S_ABREL
    want = in_a[:, None] & st["locked"] & ~st["served"]
    served, load = eng.service_ops(ec, cm, st, want, prim_r == RPC, salt + 2)
    store = eng.release_locks(ec, store, st, served)
    st["locked"] = st["locked"] & ~served
    st = eng.account_round(ec, cm, st, ST_RELEASE, served, load, prim_r, 8.0)
    st = dict(st)
    st["served"] = st["served"] | served
    done_a = in_a & ~st["locked"].any(1)
    st = eng.finish_abort(st, done_a)
    st["clock"] = jnp.where(done_a, st["clock"] + 1, st["clock"])
    st["ts_hi"] = jnp.where(done_a, st["clock"], st["ts_hi"])
    st["stage"] = jnp.where(done_a, S_READ, st["stage"])
    st["served"] = jnp.where(done_a[:, None], False, st["served"])
    st["lat_us"] = jnp.where(done_a, 0.0, st["lat_us"])
    st["rounds"] = jnp.where(done_a, 0, st["rounds"])

    # ---- LOG --------------------------------------------------------------------
    prim_g = ec.hybrid[ST_LOG]
    in_g = st["stage"] == S_LOG
    ops_g = in_g[:, None] & st["is_w"] & st["valid"]
    load_g = jnp.full(ops_g.shape, float(cm.n_backups), jnp.float32)
    st = eng.account_round(ec, cm, st, ST_LOG, ops_g, load_g, prim_g, (4.0 * wl.rw + 8.0) * cm.n_backups)
    st["stage"] = jnp.where(in_g, S_COMMIT, st["stage"])
    st["served"] = jnp.where(in_g[:, None], False, st["served"])

    # ---- EXEC ---------------------------------------------------------------------
    in_e = st["stage"] == S_EXEC
    st["exec_left"] = jnp.where(in_e, jnp.maximum(st["exec_left"] - 1, 0), st["exec_left"])
    done_e = in_e & (st["exec_left"] == 0)
    wv = jax.vmap(wl.execute)(st["keys"], st["is_w"], st["valid"], st["rvals"])
    st["wvals"] = jnp.where(done_e[:, None, None], wv, st["wvals"])
    st["stage"] = jnp.where(done_e, S_LOG, st["stage"])

    # ---- LOCK WS (CAS tts + READ, then double-check W1) ----------------------------
    prim_l = ec.hybrid[ST_LOCK]
    in_l = st["stage"] == S_LOCKW
    ws = st["valid"] & st["is_w"]
    pend = in_l[:, None] & ws & ~st["locked"]
    served, load = eng.service_ops(ec, cm, st, pend, prim_l == RPC, salt + 3)
    st = eng.account_round(ec, cm, st, ST_LOCK, served, load, prim_l, 24.0 + 4.0 * wl.rw, n_verbs=2)
    st = dict(st)
    won, store = eng.try_lock(ec, store, st, served, st["ts_hi"][:, None] + 0 * served, st["ts_lo"][:, None] + 0 * served)
    st["locked"] = st["locked"] | won
    # read-modify-write: fetch newest committed version under the lock
    wts = _vts(store, st["keys"])
    found, slot = _best_version(wts, TS(st["ts_hi"][:, None], st["ts_lo"][:, None]))
    got = store["vdata"][st["keys"].reshape(-1), slot.reshape(-1)].reshape(st["wvals"].shape)
    st["rvals"] = jnp.where(won[:, :, None], got, st["rvals"])
    vver = store["vver"][st["keys"].reshape(-1), slot.reshape(-1)].reshape(won.shape)
    st["ver_seen"] = jnp.where(won, vver, st["ver_seen"])
    # double-check W1 under the lock (paper's atomicity fix)
    w1_ok = _check_w1(store, st, won)
    lost = served & ~won
    fail_l = in_l & (lost.any(1) | (won & ~w1_ok).any(1) | (won & ~found).any(1))
    locked_all = in_l & ~(ws & ~st["locked"]).any(1) & ~fail_l
    st = _abort_to_retry(st, fail_l)
    st["stage"] = jnp.where(locked_all, S_EXEC, st["stage"])
    st["exec_left"] = jnp.where(locked_all, wl.exec_ticks, st["exec_left"])
    st["served"] = jnp.where((locked_all | fail_l)[:, None], False, st["served"])

    # ---- RTS bump (validated CAS-max) ------------------------------------------------
    # The rts CAS is conditional on the read still being valid: Cond R2 must
    # still hold and the version we read must still be the newest < ctts —
    # otherwise a writer serialized between our read and our rts update and
    # we must abort (the handler does this check atomically server-side; the
    # one-sided path gets it from the CAS+READ doorbell results).
    prim_t = ec.hybrid[ST_VALIDATE]
    in_t = st["stage"] == S_RTS
    rs = st["valid"] & ~st["is_w"]
    want = in_t[:, None] & rs & ~st["served"]
    served, load = eng.service_ops(ec, cm, st, want, prim_t == RPC, salt + 4)
    st = eng.account_round(ec, cm, st, ST_VALIDATE, served, load, prim_t, 16.0)
    st = dict(st)
    wts_now = _vts(store, st["keys"])
    ctts_now = TS(st["ts_hi"][:, None], st["ts_lo"][:, None])
    found_now, slot_now = _best_version(wts_now, ctts_now)
    seen = TS(st["wts_seen_hi"], st["wts_seen_lo"])
    best_now = TS(
        jnp.take_along_axis(wts_now.hi, slot_now[..., None], axis=-1)[..., 0],
        jnp.take_along_axis(wts_now.lo, slot_now[..., None], axis=-1)[..., 0],
    )
    lock_now = TS(eng.gather_rows(store["lock_hi"], st["keys"]), eng.gather_rows(store["lock_lo"], st["keys"]))
    r2_now = ts_is_zero(lock_now) | ts_lt(ctts_now, lock_now)
    still_ok = found_now & ts_eq(best_now, seen) & r2_now
    bad_t = served & ~still_ok
    fail_t = in_t & bad_t.any(1)
    served = served & still_ok
    # lexicographic scatter-max of ctts into rts
    keys_f = st["keys"].reshape(-1)
    sf = served.reshape(-1)
    idx = jnp.where(sf, keys_f, ec.n_records)
    ch = jnp.repeat(st["ts_hi"], st["keys"].shape[1])
    cl = jnp.repeat(st["ts_lo"], st["keys"].shape[1])
    store = dict(store)
    cand_hi = jnp.full((ec.n_records,), -(2**31), jnp.int32).at[idx].max(
        jnp.where(sf, ch, -(2**31)), mode="drop"
    )
    at_max = sf & (ch == cand_hi[jnp.clip(idx, 0, ec.n_records - 1)])
    cand_lo = jnp.full((ec.n_records,), -(2**31), jnp.int32).at[idx].max(
        jnp.where(at_max, cl, -(2**31)), mode="drop"
    )
    rts = TS(store["rts_hi"], store["rts_lo"])
    cand = TS(cand_hi, cand_lo)
    upd = _lex_lt(rts.hi, rts.lo, cand.hi, cand.lo)
    store["rts_hi"] = jnp.where(upd, cand.hi, rts.hi)
    store["rts_lo"] = jnp.where(upd, cand.lo, rts.lo)
    st["served"] = st["served"] | served
    st = _abort_to_retry(st, fail_t)
    done_t = in_t & ~fail_t & ~(rs & ~st["served"]).any(1)
    has_ws = (st["valid"] & st["is_w"]).any(1)
    # read-only transactions commit here (no lock/log/commit rounds)
    ro_done = done_t & ~has_ws
    st = eng.finish_commit(ec, cm, st, ro_done)
    st["stage"] = jnp.where(ro_done, -1, st["stage"])
    st["stage"] = jnp.where(done_t & has_ws, S_LOCKW, st["stage"])
    st["served"] = jnp.where((done_t | fail_t)[:, None], False, st["served"])

    # ---- READ (atomic double-read + version selection + W1 precheck) -------------------
    prim_f = ec.hybrid[ST_FETCH]
    in_f = st["stage"] == S_READ
    want = in_f[:, None] & st["valid"] & ~st["served"]
    served, load = eng.service_ops(ec, cm, st, want, prim_f == RPC, salt + 5)
    # double-read = 2 READ verbs in one doorbell batch
    st = eng.account_round(ec, cm, st, ST_FETCH, served, load, prim_f, 2 * (24.0 + 4.0 * wl.rw * 4), n_verbs=2)
    st = dict(st)
    wts = _vts(store, st["keys"])
    ctts = TS(st["ts_hi"][:, None], st["ts_lo"][:, None])
    found, slot = _best_version(wts, ctts)
    lock = TS(eng.gather_rows(store["lock_hi"], st["keys"]), eng.gather_rows(store["lock_lo"], st["keys"]))
    r2 = ts_is_zero(lock) | ts_lt(ctts, lock)
    rs = st["valid"] & ~st["is_w"]
    got = store["vdata"][st["keys"].reshape(-1), slot.reshape(-1)].reshape(st["rvals"].shape)
    rs_served = served & rs
    st["rvals"] = jnp.where(rs_served[:, :, None], got, st["rvals"])
    vver = store["vver"][st["keys"].reshape(-1), slot.reshape(-1)].reshape(served.shape)
    st["ver_seen"] = jnp.where(rs_served, vver, st["ver_seen"])
    # remember the READ version's wts so the rts stage can re-validate
    best_hi = jnp.take_along_axis(wts.hi, slot[..., None], axis=-1)[..., 0]
    best_lo = jnp.take_along_axis(wts.lo, slot[..., None], axis=-1)[..., 0]
    st["wts_seen_hi"] = jnp.where(rs_served, best_hi, st["wts_seen_hi"])
    st["wts_seen_lo"] = jnp.where(rs_served, best_lo, st["wts_seen_lo"])
    # clock drift adjustment from observed remote timestamps
    rts_obs = eng.gather_rows(store["rts_hi"], st["keys"])
    obs = jnp.maximum(jnp.where(served, wts.hi.max(-1), 0).max(1), jnp.where(served, rts_obs, 0).max(1))
    st["clock"] = jnp.maximum(st["clock"], obs)
    # failures: RS needs (R1 & R2); WS precheck W1
    w1 = _check_w1(store, st, served & st["is_w"])
    bad_rs = rs_served & ~(found & r2)
    bad_ws = served & st["is_w"] & ~w1
    st["served"] = st["served"] | served
    fail_f = in_f & (bad_rs.any(1) | bad_ws.any(1))
    done_f = in_f & ~(st["valid"] & ~st["served"]).any(1) & ~fail_f
    st = _abort_to_retry(st, fail_f)
    st["stage"] = jnp.where(done_f, S_RTS, st["stage"])
    st["served"] = jnp.where((done_f | fail_f)[:, None], False, st["served"])
    return st, store


STAGES_USED = ("fetch", "validate", "lock", "log", "commit", "release")
