"""MVCC (paper §4.4): tts | rts | wts[4] | record[4].

Read (RS): atomic double-read of the tuple; Cond R1 — a committed version
with the largest wts < ctts exists among the 4 static slots; Cond R2 — tts
is 0 or > ctts.  Abort if either fails (slot overflow shows up as R1
failure — the paper measured <=4.2% of read aborts from overflow at 4
slots).  Then bump rts to max(rts, ctts) via CAS-max (retry-until-success
is contention, not conflict).

Write (WS): read metadata, check Cond W1 (ctts > max wts and > rts), CAS
the lock (tts), then RE-CHECK W1 with the returned metadata — the paper's
*double-read/double-check* closing the W1/W2 atomicity gap.  Commit
overwrites the OLDEST wts slot + its record, then unlocks.

Local clocks advance to any larger observed wts/rts (drift limiter, §4.4).
Declared as a rounds.StageSpec table; read-only transactions commit at the
RTS stage via the declarative ``StageSpec.ro_commit`` fast-path flag (no
lock/log/commit rounds) — a table entry, not a code fork.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import registry
from repro.core import rounds
from repro.core.costmodel import (
    ST_COMMIT,
    ST_EXEC,
    ST_FETCH,
    ST_LOCK,
    ST_LOG,
    ST_RELEASE,
    ST_VALIDATE,
)
from repro.core.rounds import StageOut, StageSpec
from repro.core.timestamps import TS, ts_eq, ts_is_zero, ts_lt
from repro.kernels import ops as kops

S_READ, S_RTS, S_LOCKW, S_EXEC, S_LOG, S_COMMIT, S_ABREL = range(7)


def _vts(ec, store, keys) -> TS:
    """Version timestamps at keys: (N,K,slots) TS (one batched plane round)."""
    hi, lo = eng.read_rows_many(ec, (store["wts_hi"], store["wts_lo"]), keys)
    return TS(hi, lo)


def _lex_lt(ah, al, bh, bl):
    return (ah < bh) | ((ah == bh) & (al < bl))


def _best_version(wts: TS, ctts: TS):
    """Largest wts strictly < ctts among slots. Returns (found, slot_idx)."""
    ch, cl = ctts.hi[..., None], ctts.lo[..., None]
    cand = _lex_lt(wts.hi, wts.lo, ch, cl) & ~((wts.hi == 0) & (wts.lo == 0))
    bh = jnp.where(cand, wts.hi, jnp.int32(-(2**31)))
    bl = jnp.where(cand, wts.lo, jnp.int32(-(2**31)))
    best_h = bh.max(-1, keepdims=True)
    is_h = cand & (wts.hi == best_h)
    bl2 = jnp.where(is_h, bl, jnp.int32(-(2**31)))
    best_l = bl2.max(-1, keepdims=True)
    winner = is_h & (wts.lo == best_l)
    found = cand.any(-1)
    slot = jnp.argmax(winner, axis=-1)
    return found, slot.astype(jnp.int32)


def _version_pick(ec, wts: TS, ctts: TS, lock: TS = None):
    """Cond R1 version pick (+ Cond R2 when ``lock`` is given), routed
    through the kernel plane (DESIGN.md §9).

    wts is (..., S); ctts/lock broadcast against the (...) op batch.
    Returns (found, slot, r2_ok) with r2_ok None when ``lock`` is None —
    bitwise-equal across planes (the jnp path IS the original inline
    ``_best_version`` + R2 check, so pinned golden counters cannot move).
    """
    if kops.is_pallas(ec.kernel_plane):
        shp = wts.hi.shape[:-1]
        S = wts.hi.shape[-1]

        def flat(a):
            return jnp.broadcast_to(a, shp).reshape(-1)

        z = jnp.zeros(shp, jnp.int32)
        lh, ll = (lock.hi, lock.lo) if lock is not None else (z, z)
        found, slot, ok = kops.version_select(
            wts.hi.reshape(-1, S), wts.lo.reshape(-1, S),
            flat(ctts.hi), flat(ctts.lo), flat(lh), flat(ll),
            plane=ec.kernel_plane,
        )
        r2 = ok.reshape(shp) if lock is not None else None
        return found.reshape(shp), slot.reshape(shp), r2
    found, slot = _best_version(wts, ctts)
    r2 = None if lock is None else ts_is_zero(lock) | ts_lt(ctts, lock)
    return found, slot, r2


def _max_wts(wts: TS) -> TS:
    bh = wts.hi.max(-1, keepdims=True)
    is_h = wts.hi == bh
    bl = jnp.where(is_h, wts.lo, jnp.int32(-(2**31))).max(-1)
    return TS(bh[..., 0], bl)


def _oldest_slot(wts: TS):
    bh = wts.hi.min(-1, keepdims=True)
    is_h = wts.hi == bh
    bl = jnp.where(is_h, wts.lo, jnp.int32(2**31 - 1)).min(-1, keepdims=True)
    winner = is_h & (wts.lo == bl)
    return jnp.argmax(winner, axis=-1).astype(jnp.int32)


def _check_w1(ec, store, st, ops) -> jnp.ndarray:
    """Cond W1 per op: ctts > max(wts) and ctts > rts."""
    wts = _vts(ec, store, st["keys"])
    mx = _max_wts(wts)
    rh, rl = eng.read_rows_many(ec, (store["rts_hi"], store["rts_lo"]), st["keys"])
    rts = TS(rh, rl)
    me = TS(st["ts_hi"][:, None], st["ts_lo"][:, None])
    ok = _lex_lt(mx.hi, mx.lo, me.hi, me.lo) & _lex_lt(rts.hi, rts.lo, me.hi, me.lo)
    return ok | ~ops


def _commit_effect(ec, cm, wl, st, store, in_c, served, salt):
    """Overwrite the OLDEST version slot + its record, then unlock.
    wts pair + version counter ride one doorbell-batched plane round."""
    st = dict(st)
    wh, wl_, ver = eng.read_rows_many(
        ec, (store["wts_hi"], store["wts_lo"], store["ver"]), st["keys"]
    )
    wts = TS(wh, wl_)
    oldest = _oldest_slot(wts)  # (N,K)
    keys_f = st["keys"].reshape(-1)
    eff = served.reshape(-1)
    idx_k = jnp.where(eff, keys_f, ec.n_records)
    idx_s = oldest.reshape(-1)
    store = dict(store)
    new_ver = ver + 1
    store["wts_hi"] = eng.write_rows2(
        ec, store["wts_hi"], idx_k, idx_s, jnp.repeat(st["ts_hi"], st["keys"].shape[1])
    )
    store["wts_lo"] = eng.write_rows2(
        ec, store["wts_lo"], idx_k, idx_s, jnp.repeat(st["ts_lo"], st["keys"].shape[1])
    )
    store["vdata"] = eng.write_rows2(
        ec, store["vdata"], idx_k, idx_s, st["wvals"].reshape(-1, wl.rw)
    )
    store["vver"] = eng.write_rows2(ec, store["vver"], idx_k, idx_s, new_ver.reshape(-1))
    store["ver"] = eng.write_rows(ec, store["ver"], idx_k, 1, op="add")
    rel = (served & st["locked"]).reshape(-1)
    idx_r = jnp.where(rel, keys_f, ec.n_records)
    store["lock_hi"] = eng.write_rows(ec, store["lock_hi"], idx_r, 0)
    store["lock_lo"] = eng.write_rows(ec, store["lock_lo"], idx_r, 0)
    st["locked"] = st["locked"] & ~served
    return StageOut(st, store)


def _lock_effect(ec, cm, wl, st, store, in_l, served, salt):
    """CAS tts + READ, then double-check W1 under the lock (the paper's
    atomicity fix); fetch the newest committed version for read-modify-write."""
    st = dict(st)
    won, store = eng.try_lock(
        ec,
        store,
        st,
        served,
        jnp.broadcast_to(st["ts_hi"][:, None], served.shape),
        jnp.broadcast_to(st["ts_lo"][:, None], served.shape),
    )
    st["locked"] = st["locked"] | won
    wts = _vts(ec, store, st["keys"])
    found, slot, _ = _version_pick(ec, wts, TS(st["ts_hi"][:, None], st["ts_lo"][:, None]))
    got = eng.read_rows2(ec, store["vdata"], st["keys"], slot)
    st["rvals"] = jnp.where(won[:, :, None], got, st["rvals"])
    vver = eng.read_rows2(ec, store["vver"], st["keys"], slot)
    st["ver_seen"] = jnp.where(won, vver, st["ver_seen"])
    w1_ok = _check_w1(ec, store, st, won)
    lost = served & ~won
    fail = in_l & (lost.any(1) | (won & ~w1_ok).any(1) | (won & ~found).any(1))
    ws = st["valid"] & st["is_w"]
    return StageOut(
        st,
        store,
        fail=fail,
        served_acc=jnp.zeros_like(served),
        outstanding=ws & ~st["locked"],
    )


def _rts_effect(ec, cm, wl, st, store, in_t, served, salt):
    """Validated rts CAS-max: conditional on the read still being valid —
    Cond R2 must still hold and the version we read must still be the
    newest < ctts, otherwise a writer serialized between our read and our
    rts update and we must abort (the handler does this check atomically
    server-side; the one-sided path gets it from the CAS+READ doorbell)."""
    st = dict(st)
    wts_now = _vts(ec, store, st["keys"])
    ctts_now = TS(st["ts_hi"][:, None], st["ts_lo"][:, None])
    lh, ll = eng.read_rows_many(ec, (store["lock_hi"], store["lock_lo"]), st["keys"])
    lock_now = TS(lh, ll)
    found_now, slot_now, r2_now = _version_pick(ec, wts_now, ctts_now, lock_now)
    seen = TS(st["wts_seen_hi"], st["wts_seen_lo"])
    best_now = TS(
        jnp.take_along_axis(wts_now.hi, slot_now[..., None], axis=-1)[..., 0],
        jnp.take_along_axis(wts_now.lo, slot_now[..., None], axis=-1)[..., 0],
    )
    still_ok = found_now & ts_eq(best_now, seen) & r2_now
    bad_t = served & ~still_ok
    fail = in_t & bad_t.any(1)
    served = served & still_ok
    # lexicographic scatter-max of ctts into rts (owner-local when sharded)
    keys_f = st["keys"].reshape(-1)
    sf = served.reshape(-1)
    idx = jnp.where(sf, keys_f, ec.n_records)
    ch = jnp.repeat(st["ts_hi"], st["keys"].shape[1])
    cl = jnp.repeat(st["ts_lo"], st["keys"].shape[1])
    store = dict(store)
    store["rts_hi"], store["rts_lo"] = eng.scatter_ts_max(
        ec, store["rts_hi"], store["rts_lo"], idx, ch, cl, sf
    )
    return StageOut(st, store, fail=fail, served_acc=served)


def _read_effect(ec, cm, wl, st, store, in_f, served, salt):
    """Atomic double-read + version selection + W1 precheck."""
    st = dict(st)
    wts = _vts(ec, store, st["keys"])
    ctts = TS(st["ts_hi"][:, None], st["ts_lo"][:, None])
    lh, ll, rts_obs = eng.read_rows_many(
        ec, (store["lock_hi"], store["lock_lo"], store["rts_hi"]), st["keys"]
    )
    lock = TS(lh, ll)
    found, slot, r2 = _version_pick(ec, wts, ctts, lock)
    rs = st["valid"] & ~st["is_w"]
    got = eng.read_rows2(ec, store["vdata"], st["keys"], slot)
    rs_served = served & rs
    st["rvals"] = jnp.where(rs_served[:, :, None], got, st["rvals"])
    vver = eng.read_rows2(ec, store["vver"], st["keys"], slot)
    st["ver_seen"] = jnp.where(rs_served, vver, st["ver_seen"])
    # remember the READ version's wts so the rts stage can re-validate
    best_hi = jnp.take_along_axis(wts.hi, slot[..., None], axis=-1)[..., 0]
    best_lo = jnp.take_along_axis(wts.lo, slot[..., None], axis=-1)[..., 0]
    st["wts_seen_hi"] = jnp.where(rs_served, best_hi, st["wts_seen_hi"])
    st["wts_seen_lo"] = jnp.where(rs_served, best_lo, st["wts_seen_lo"])
    # clock drift adjustment from observed remote timestamps
    obs = jnp.maximum(
        jnp.where(served, wts.hi.max(-1), 0).max(1), jnp.where(served, rts_obs, 0).max(1)
    )
    st["clock"] = jnp.maximum(st["clock"], obs)
    # failures: RS needs (R1 & R2); WS precheck W1
    w1 = _check_w1(ec, store, st, served & st["is_w"])
    bad_rs = rs_served & ~(found & r2)
    bad_ws = served & st["is_w"] & ~w1
    return StageOut(st, store, fail=in_f & (bad_rs.any(1) | bad_ws.any(1)))


SPECS = (
    StageSpec(
        stage=S_COMMIT,
        canon=ST_COMMIT,
        ops=rounds.ops_write_set,
        effect=_commit_effect,
        done="commit",
        salt_off=1,
        fuse_absorbs=ST_LOG,
    ),
    StageSpec(
        stage=S_ABREL,
        canon=ST_RELEASE,
        ops=rounds.ops_locked,
        effect=rounds.release_effect,
        done="abort",
        next_stage=S_READ,
        new_ts=True,  # MVCC retries take a fresh (larger) timestamp
        salt_off=2,
    ),
    StageSpec(stage=S_LOG, canon=ST_LOG, kind=rounds.LOG, next_stage=S_COMMIT),
    StageSpec(
        stage=S_EXEC,
        canon=ST_EXEC,
        kind=rounds.EXEC,
        next_stage=S_LOG,
        fuse_next=S_COMMIT,
    ),
    StageSpec(
        stage=S_LOCKW,
        canon=ST_LOCK,
        ops=rounds.ops_lock_pending(write_only=True),
        effect=_lock_effect,
        next_stage=S_EXEC,
        start_exec=True,
        retry_stage=S_READ,
        abrel_stage=S_ABREL,
        new_ts=True,
        salt_off=3,
    ),
    StageSpec(
        stage=S_RTS,
        canon=ST_VALIDATE,
        ops=rounds.ops_read_set,
        effect=_rts_effect,
        # read-only txns commit at this stage (declarative RO fast path);
        # read-write txns proceed to the write-set lock round
        ro_commit=True,
        next_stage=S_LOCKW,
        retry_stage=S_READ,
        abrel_stage=S_ABREL,
        new_ts=True,
        salt_off=4,
    ),
    StageSpec(
        stage=S_READ,
        canon=ST_FETCH,
        ops=rounds.ops_valid,
        effect=_read_effect,
        next_stage=S_RTS,
        retry_stage=S_READ,
        abrel_stage=S_ABREL,
        new_ts=True,
        salt_off=5,
    ),
)

tick = rounds.make_tick(specs=SPECS, start_stage=S_READ, salt_mult=37)

STAGES_USED = ("fetch", "validate", "lock", "log", "commit", "release")

registry.register_protocol(
    "mvcc",
    tick=tick,
    stages=STAGES_USED,
    # ro_commit: read-only txns commit at the validate stage (S_RTS above)
    capabilities=registry.Caps(ro_commit=True),
)
