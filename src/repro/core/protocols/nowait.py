"""NOWAIT (paper §4.2): registry variant of twopl (abort on any conflict).

Import shim only — the protocol itself is registered by
``repro.core.protocols.twopl`` as ``register_protocol("nowait",
variant={"wait_die": False})``.
"""
from repro.core.protocols.twopl import NOWAIT as _entry
from repro.core.protocols.twopl import STAGES_USED  # noqa: F401

tick = _entry.tick
