"""NOWAIT (paper §4.2): 2PL, abort immediately on any lock conflict."""
from repro.core.protocols.twopl import make_tick

tick = make_tick(wait_die=False)
STAGES_USED = ("lock", "log", "commit", "release")
