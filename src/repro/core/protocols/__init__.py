"""Built-in protocols; each module self-registers with repro.core.registry.

Import order fixes the registration (= presentation) order: the 2PL family
(twopl registers both nowait and waitdie), then occ, mvcc, sundial, calvin.
``PROTOCOLS`` survives as a read-only live view of the registry for legacy
callers (``PROTOCOLS[name].tick`` still works — entries expose ``.tick``);
new code should use :func:`repro.core.registry.get_protocol`.
"""
from repro.core import registry as _registry
from repro.core.protocols import twopl  # noqa: F401  (registers nowait + waitdie)
from repro.core.protocols import occ  # noqa: F401
from repro.core.protocols import mvcc  # noqa: F401
from repro.core.protocols import sundial  # noqa: F401
from repro.core.protocols import calvin  # noqa: F401

PROTOCOLS = _registry.ProtocolsView()
