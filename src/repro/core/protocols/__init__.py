from repro.core.protocols import calvin, mvcc, nowait, occ, sundial, waitdie  # noqa: F401

PROTOCOLS = {
    "nowait": nowait,
    "waitdie": waitdie,
    "occ": occ,
    "mvcc": mvcc,
    "sundial": sundial,
    "calvin": calvin,
}
