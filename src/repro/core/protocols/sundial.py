"""SUNDIAL (paper §4.5): lock | rts | wts | record — logical leases.

FETCH: atomic (double-)read of each tuple; for reads commit_tts =
max(commit_tts, wts).  LOCK(WS): CAS lock + READ; require wts unchanged
since fetch (read-modify-write), then commit_tts = max(commit_tts, rts+1).
VALIDATE: every RS record whose rts < commit_tts gets a lease RENEWAL —
atomic read (fail if wts changed or locked by another txn), then CAS
rts: old -> commit_tts.  One-sided renewal takes 2 dependent rounds
(read then CAS); RPC does it in one handler call — the paper's
"renew prefers two-sided" asymmetry.  COMMIT: write back WS with
wts = rts = commit_tts, unlock.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core.costmodel import (
    ONE_SIDED,
    RPC,
    ST_COMMIT,
    ST_EXEC,
    ST_FETCH,
    ST_LOCK,
    ST_LOG,
    ST_RELEASE,
    ST_VALIDATE,
    CostModel,
)
from repro.core.engine import EngineConfig, Workload
from repro.core.timestamps import TS, ts_eq, ts_is_zero, ts_lt

S_FETCH, S_EXEC, S_LOCKW, S_VALID, S_LOG, S_COMMIT, S_ABREL = range(7)
_CANON = (ST_FETCH, ST_EXEC, ST_LOCK, ST_VALIDATE, ST_LOG, ST_COMMIT, ST_RELEASE)


def canon_stage(st):
    s = st["stage"]
    canon = jnp.full_like(s, -1)
    for ps, c in enumerate(_CANON):
        canon = jnp.where(s == ps, c, canon)
    return canon


def _lex_lt(ah, al, bh, bl):
    return (ah < bh) | ((ah == bh) & (al < bl))


def _wts(store, keys) -> TS:
    return TS(eng.gather_rows(store["wts_hi"], keys), eng.gather_rows(store["wts_lo"], keys))


def _rts(store, keys) -> TS:
    return TS(eng.gather_rows(store["rts_hi"], keys), eng.gather_rows(store["rts_lo"], keys))


def _bump_commit(st, ops, cand: TS):
    """commit_tts = max(commit_tts, max over ops of cand)."""
    ch = jnp.where(ops, cand.hi, -(2**31)).max(1)
    cl = jnp.where(ops & (cand.hi == ch[:, None]), cand.lo, -(2**31)).max(1)
    lt = _lex_lt(st["commit_hi"], st["commit_lo"], ch, cl)
    st = dict(st)
    st["commit_hi"] = jnp.where(lt & ops.any(1), ch, st["commit_hi"])
    st["commit_lo"] = jnp.where(lt & ops.any(1), cl, st["commit_lo"])
    return st


def _abort_to_retry(st, fail_mask):
    has_locks = st["locked"].any(1)
    st = dict(st)
    st["stage"] = jnp.where(fail_mask, jnp.where(has_locks, S_ABREL, S_FETCH), st["stage"])
    insta = fail_mask & ~has_locks
    st = eng.finish_abort(st, insta)
    st["clock"] = jnp.where(insta, st["clock"] + 1, st["clock"])
    st["ts_hi"] = jnp.where(insta, st["clock"], st["ts_hi"])
    st["lat_us"] = jnp.where(insta, 0.0, st["lat_us"])
    st["rounds"] = jnp.where(insta, 0, st["rounds"])
    st["served"] = jnp.where(insta[:, None], False, st["served"])
    return st


def tick(ec: EngineConfig, cm: CostModel, wl: Workload, st: Dict, store: Dict, t):
    salt = t * 43
    fresh = st["stage"] < 0
    st = eng.regen_txns(ec, wl, st, fresh, new_ts=True)
    st = dict(st)
    st["stage"] = jnp.where(fresh, S_FETCH, st["stage"])
    st["commit_hi"] = jnp.where(fresh, 0, st["commit_hi"])
    st["commit_lo"] = jnp.where(fresh, 0, st["commit_lo"])
    st = eng.base_time(ec, cm, st, canon_stage(st))

    # ---- COMMIT: write back, wts = rts = commit_tts, unlock -------------------
    prim_c = ec.hybrid[ST_COMMIT]
    in_c = st["stage"] == S_COMMIT
    ws = st["valid"] & st["is_w"]
    want = in_c[:, None] & ws & ~st["served"]
    served, load = eng.service_ops(ec, cm, st, want, prim_c == RPC, salt + 1)
    keys_f = st["keys"].reshape(-1)
    eff = served.reshape(-1)
    idx = jnp.where(eff, keys_f, ec.n_records)
    K = st["keys"].shape[1]
    ch = jnp.repeat(st["commit_hi"], K)
    cl = jnp.repeat(st["ts_lo"], K)  # writer id in lo for wts uniqueness
    store = dict(store)
    store["data"] = store["data"].at[idx].set(st["wvals"].reshape(-1, wl.rw), mode="drop")
    store["wts_hi"] = store["wts_hi"].at[idx].set(ch, mode="drop")
    store["wts_lo"] = store["wts_lo"].at[idx].set(cl, mode="drop")
    store["rts_hi"] = store["rts_hi"].at[idx].set(ch, mode="drop")
    store["rts_lo"] = store["rts_lo"].at[idx].set(cl, mode="drop")
    store["ver"] = store["ver"].at[idx].add(1, mode="drop")
    rel = (served & st["locked"]).reshape(-1)
    idx_r = jnp.where(rel, keys_f, ec.n_records)
    store["lock_hi"] = store["lock_hi"].at[idx_r].set(0, mode="drop")
    store["lock_lo"] = store["lock_lo"].at[idx_r].set(0, mode="drop")
    st["locked"] = st["locked"] & ~served
    st = eng.account_round(ec, cm, st, ST_COMMIT, served, load, prim_c, 16.0 + 4.0 * wl.rw, n_verbs=2)
    st = dict(st)
    st["served"] = st["served"] | served
    done_c = in_c & ~(ws & ~st["served"]).any(1)
    st = eng.finish_commit(ec, cm, st, done_c)
    st["stage"] = jnp.where(done_c, -1, st["stage"])
    st["served"] = jnp.where(done_c[:, None], False, st["served"])

    # ---- ABORT-RELEASE ----------------------------------------------------------
    prim_r = ec.hybrid[ST_RELEASE]
    in_a = st["stage"] == S_ABREL
    want = in_a[:, None] & st["locked"] & ~st["served"]
    served, load = eng.service_ops(ec, cm, st, want, prim_r == RPC, salt + 2)
    store = eng.release_locks(ec, store, st, served)
    st["locked"] = st["locked"] & ~served
    st = eng.account_round(ec, cm, st, ST_RELEASE, served, load, prim_r, 8.0)
    st = dict(st)
    st["served"] = st["served"] | served
    done_a = in_a & ~st["locked"].any(1)
    st = eng.finish_abort(st, done_a)
    st["clock"] = jnp.where(done_a, st["clock"] + 1, st["clock"])
    st["ts_hi"] = jnp.where(done_a, st["clock"], st["ts_hi"])
    st["stage"] = jnp.where(done_a, S_FETCH, st["stage"])
    st["served"] = jnp.where(done_a[:, None], False, st["served"])
    st["lat_us"] = jnp.where(done_a, 0.0, st["lat_us"])
    st["rounds"] = jnp.where(done_a, 0, st["rounds"])

    # ---- LOG ----------------------------------------------------------------------
    prim_g = ec.hybrid[ST_LOG]
    in_g = st["stage"] == S_LOG
    ops_g = in_g[:, None] & st["is_w"] & st["valid"]
    load_g = jnp.full(ops_g.shape, float(cm.n_backups), jnp.float32)
    st = eng.account_round(ec, cm, st, ST_LOG, ops_g, load_g, prim_g, (4.0 * wl.rw + 8.0) * cm.n_backups)
    st["stage"] = jnp.where(in_g, S_COMMIT, st["stage"])
    st["served"] = jnp.where(in_g[:, None], False, st["served"])

    # ---- VALIDATE / lease renewal ---------------------------------------------------
    # EVERY RS record is validated at commit: the version read must be
    # unchanged (wts == wts_seen) — a replaced version means our commit_tts
    # may exceed the OLD version's lease, which rts_now (the new version's)
    # can no longer witness.  Leases short of commit_tts are then RENEWED
    # (CAS rts -> commit_tts), failing if the tuple is locked by a writer.
    prim_v = ec.hybrid[ST_VALIDATE]
    in_v = st["stage"] == S_VALID
    rs = st["valid"] & ~st["is_w"]
    rts_now = _rts(store, st["keys"])
    cm_ts = TS(st["commit_hi"][:, None], st["commit_lo"][:, None])
    needs = rs & _lex_lt(rts_now.hi, rts_now.lo, cm_ts.hi, cm_ts.lo)
    # one-sided renewal: round 1 = atomic read, round 2 = CAS (substep);
    # RPC renewal: single handler call.  prim_v may be traced (batched
    # sweep), so the round count is selected, not Python-branched.
    rounds_needed = jnp.where(jnp.asarray(prim_v) == RPC, 1, 2)
    want = in_v[:, None] & rs & ~st["served"]
    served, load = eng.service_ops(ec, cm, st, want, prim_v == RPC, salt + 3)
    st = eng.account_round(ec, cm, st, ST_VALIDATE, served, load, prim_v, 24.0)
    st = dict(st)
    final = st["substep"] >= (rounds_needed - 1)
    eff = served & final[:, None]
    wts_now = _wts(store, st["keys"])
    seen = TS(st["wts_seen_hi"], st["wts_seen_lo"])
    lock = TS(eng.gather_rows(store["lock_hi"], st["keys"]), eng.gather_rows(store["lock_lo"], st["keys"]))
    mine = ts_eq(lock, TS(st["ts_hi"][:, None], st["ts_lo"][:, None]))
    unchanged = ts_eq(wts_now, seen)
    renew_ok = unchanged & (ts_is_zero(lock) | mine)
    bad = eff & ((needs & ~renew_ok) | ~unchanged)
    # CAS rts -> commit_tts (lexicographic scatter-max, as MVCC)
    ok_eff = (eff & renew_ok).reshape(-1)
    keys_f = st["keys"].reshape(-1)
    idx = jnp.where(ok_eff, keys_f, ec.n_records)
    ch = jnp.repeat(st["commit_hi"], st["keys"].shape[1])
    cl = jnp.repeat(st["commit_lo"], st["keys"].shape[1])
    cand_hi = jnp.full((ec.n_records,), -(2**31), jnp.int32).at[idx].max(
        jnp.where(ok_eff, ch, -(2**31)), mode="drop"
    )
    at_max = ok_eff & (ch == cand_hi[jnp.clip(idx, 0, ec.n_records - 1)])
    cand_lo = jnp.full((ec.n_records,), -(2**31), jnp.int32).at[idx].max(
        jnp.where(at_max, cl, -(2**31)), mode="drop"
    )
    upd = _lex_lt(store["rts_hi"], store["rts_lo"], cand_hi, cand_lo)
    store = dict(store)
    store["rts_hi"] = jnp.where(upd, cand_hi, store["rts_hi"])
    store["rts_lo"] = jnp.where(upd, cand_lo, store["rts_lo"])

    st["served"] = st["served"] | (served & final[:, None])
    partial = in_v & served.any(1) & ~final
    st["substep"] = jnp.where(partial, st["substep"] + 1, st["substep"])
    fail_v = in_v & bad.any(1)
    done_v = in_v & ~(rs & ~st["served"]).any(1) & ~fail_v
    st = _abort_to_retry(st, fail_v)
    st["stage"] = jnp.where(done_v, S_LOG, st["stage"])
    st["served"] = jnp.where((done_v | fail_v)[:, None], False, st["served"])
    st["substep"] = jnp.where(done_v | fail_v, 0, st["substep"])

    # ---- LOCK WS ----------------------------------------------------------------------
    prim_l = ec.hybrid[ST_LOCK]
    in_l = st["stage"] == S_LOCKW
    ws = st["valid"] & st["is_w"]
    pend = in_l[:, None] & ws & ~st["locked"]
    served, load = eng.service_ops(ec, cm, st, pend, prim_l == RPC, salt + 4)
    st = eng.account_round(ec, cm, st, ST_LOCK, served, load, prim_l, 24.0 + 4.0 * wl.rw, n_verbs=2)
    st = dict(st)
    won, store = eng.try_lock(
        ec, store, st, served, st["ts_hi"][:, None] + 0 * served, st["ts_lo"][:, None] + 0 * served
    )
    st["locked"] = st["locked"] | won
    wts_now = _wts(store, st["keys"])
    seen = TS(st["wts_seen_hi"], st["wts_seen_lo"])
    unchanged = ts_eq(wts_now, seen)
    lost = served & ~won
    fail_l = in_l & (lost.any(1) | (won & ~unchanged).any(1))
    # commit_tts = max(commit_tts, rts + 1)
    rts_now = _rts(store, st["keys"])
    st = _bump_commit(st, won, TS(rts_now.hi + 1, jnp.zeros_like(rts_now.lo)))
    locked_all = in_l & ~(ws & ~st["locked"]).any(1) & ~fail_l
    st = _abort_to_retry(st, fail_l)
    st["stage"] = jnp.where(locked_all, S_VALID, st["stage"])
    st["served"] = jnp.where((locked_all | fail_l)[:, None], False, st["served"])

    # ---- EXEC --------------------------------------------------------------------------
    in_e = st["stage"] == S_EXEC
    st["exec_left"] = jnp.where(in_e, jnp.maximum(st["exec_left"] - 1, 0), st["exec_left"])
    done_e = in_e & (st["exec_left"] == 0)
    wv = jax.vmap(wl.execute)(st["keys"], st["is_w"], st["valid"], st["rvals"])
    st["wvals"] = jnp.where(done_e[:, None, None], wv, st["wvals"])
    st["stage"] = jnp.where(done_e, S_LOCKW, st["stage"])

    # ---- FETCH (atomic tuple read; reads order after writers) ----------------------------
    prim_f = ec.hybrid[ST_FETCH]
    in_f = st["stage"] == S_FETCH
    want = in_f[:, None] & st["valid"] & ~st["served"]
    served, load = eng.service_ops(ec, cm, st, want, prim_f == RPC, salt + 5)
    st = eng.account_round(ec, cm, st, ST_FETCH, served, load, prim_f, 2 * (24.0 + 4.0 * wl.rw), n_verbs=2)
    st = dict(st)
    got = eng.gather_rows(store["data"], st["keys"])
    st["rvals"] = jnp.where(served[:, :, None], got, st["rvals"])
    st["ver_seen"] = jnp.where(served, eng.gather_rows(store["ver"], st["keys"]), st["ver_seen"])
    wts_now = _wts(store, st["keys"])
    st["wts_seen_hi"] = jnp.where(served, wts_now.hi, st["wts_seen_hi"])
    st["wts_seen_lo"] = jnp.where(served, wts_now.lo, st["wts_seen_lo"])
    rs = st["valid"] & ~st["is_w"]
    st = _bump_commit(st, served & rs, wts_now)
    st["served"] = st["served"] | served
    done_f = in_f & ~(st["valid"] & ~st["served"]).any(1)
    st["stage"] = jnp.where(done_f, S_EXEC, st["stage"])
    st["exec_left"] = jnp.where(done_f, wl.exec_ticks, st["exec_left"])
    st["served"] = jnp.where(done_f[:, None], False, st["served"])
    return st, store


STAGES_USED = ("fetch", "lock", "validate", "log", "commit", "release")
