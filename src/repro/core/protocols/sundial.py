"""SUNDIAL (paper §4.5): lock | rts | wts | record — logical leases.

FETCH: atomic (double-)read of each tuple; for reads commit_tts =
max(commit_tts, wts).  LOCK(WS): CAS lock + READ; require wts unchanged
since fetch (read-modify-write), then commit_tts = max(commit_tts, rts+1).
VALIDATE: every RS record whose rts < commit_tts gets a lease RENEWAL —
atomic read (fail if wts changed or locked by another txn), then CAS
rts: old -> commit_tts.  One-sided renewal takes 2 dependent rounds
(read then CAS); RPC does it in one handler call — the paper's
"renew prefers two-sided" asymmetry.  COMMIT: write back WS with
wts = rts = commit_tts, unlock.  Declared as a rounds.StageSpec table.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import registry
from repro.core import rounds
from repro.core.costmodel import (
    RPC,
    ST_COMMIT,
    ST_EXEC,
    ST_FETCH,
    ST_LOCK,
    ST_LOG,
    ST_RELEASE,
    ST_VALIDATE,
)
from repro.core.rounds import StageOut, StageSpec
from repro.core.timestamps import TS, ts_eq, ts_is_zero

S_FETCH, S_EXEC, S_LOCKW, S_VALID, S_LOG, S_COMMIT, S_ABREL = range(7)


def _lex_lt(ah, al, bh, bl):
    return (ah < bh) | ((ah == bh) & (al < bl))


def _wts(ec, store, keys) -> TS:
    hi, lo = eng.read_rows_many(ec, (store["wts_hi"], store["wts_lo"]), keys)
    return TS(hi, lo)


def _rts(ec, store, keys) -> TS:
    hi, lo = eng.read_rows_many(ec, (store["rts_hi"], store["rts_lo"]), keys)
    return TS(hi, lo)


def _bump_commit(st, ops, cand: TS):
    """commit_tts = max(commit_tts, max over ops of cand)."""
    ch = jnp.where(ops, cand.hi, -(2**31)).max(1)
    cl = jnp.where(ops & (cand.hi == ch[:, None]), cand.lo, -(2**31)).max(1)
    lt = _lex_lt(st["commit_hi"], st["commit_lo"], ch, cl)
    st = dict(st)
    st["commit_hi"] = jnp.where(lt & ops.any(1), ch, st["commit_hi"])
    st["commit_lo"] = jnp.where(lt & ops.any(1), cl, st["commit_lo"])
    return st


def _commit_effect(ec, cm, wl, st, store, in_c, served, salt):
    """Write back WS with wts = rts = commit_tts, then unlock."""
    st = dict(st)
    keys_f = st["keys"].reshape(-1)
    eff = served.reshape(-1)
    idx = jnp.where(eff, keys_f, ec.n_records)
    K = st["keys"].shape[1]
    ch = jnp.repeat(st["commit_hi"], K)
    cl = jnp.repeat(st["ts_lo"], K)  # writer id in lo for wts uniqueness
    store = dict(store)
    store["data"] = eng.write_rows(ec, store["data"], idx, st["wvals"].reshape(-1, wl.rw))
    store["wts_hi"] = eng.write_rows(ec, store["wts_hi"], idx, ch)
    store["wts_lo"] = eng.write_rows(ec, store["wts_lo"], idx, cl)
    store["rts_hi"] = eng.write_rows(ec, store["rts_hi"], idx, ch)
    store["rts_lo"] = eng.write_rows(ec, store["rts_lo"], idx, cl)
    store["ver"] = eng.write_rows(ec, store["ver"], idx, 1, op="add")
    rel = (served & st["locked"]).reshape(-1)
    idx_r = jnp.where(rel, keys_f, ec.n_records)
    store["lock_hi"] = eng.write_rows(ec, store["lock_hi"], idx_r, 0)
    store["lock_lo"] = eng.write_rows(ec, store["lock_lo"], idx_r, 0)
    st["locked"] = st["locked"] & ~served
    return StageOut(st, store)


def _validate_effect(ec, cm, wl, st, store, in_v, served, salt):
    """Lease renewal: EVERY RS record is validated at commit — the version
    read must be unchanged (wts == wts_seen); a replaced version means our
    commit_tts may exceed the OLD version's lease, which rts_now (the new
    version's) can no longer witness.  Leases short of commit_tts are then
    RENEWED (CAS rts -> commit_tts), failing if locked by a writer."""
    st = dict(st)
    prim_v = ec.hybrid[ST_VALIDATE]
    rs = st["valid"] & ~st["is_w"]
    rts_now = _rts(ec, store, st["keys"])
    cm_ts = TS(st["commit_hi"][:, None], st["commit_lo"][:, None])
    needs = rs & _lex_lt(rts_now.hi, rts_now.lo, cm_ts.hi, cm_ts.lo)
    # one-sided renewal: round 1 = atomic read, round 2 = CAS (substep);
    # RPC renewal: single handler call.  prim_v may be traced (batched
    # sweep), so the round count is selected, not Python-branched.
    rounds_needed = jnp.where(jnp.asarray(prim_v) == RPC, 1, 2)
    final = st["substep"] >= (rounds_needed - 1)
    eff = served & final[:, None]
    wts_now = _wts(ec, store, st["keys"])
    seen = TS(st["wts_seen_hi"], st["wts_seen_lo"])
    lh, ll = eng.read_rows_many(ec, (store["lock_hi"], store["lock_lo"]), st["keys"])
    lock = TS(lh, ll)
    mine = ts_eq(lock, TS(st["ts_hi"][:, None], st["ts_lo"][:, None]))
    unchanged = ts_eq(wts_now, seen)
    renew_ok = unchanged & (ts_is_zero(lock) | mine)
    bad = eff & ((needs & ~renew_ok) | ~unchanged)
    # CAS rts -> commit_tts (lexicographic scatter-max, as MVCC; owner-local
    # when node-sharded)
    ok_eff = (eff & renew_ok).reshape(-1)
    keys_f = st["keys"].reshape(-1)
    idx = jnp.where(ok_eff, keys_f, ec.n_records)
    ch = jnp.repeat(st["commit_hi"], st["keys"].shape[1])
    cl = jnp.repeat(st["commit_lo"], st["keys"].shape[1])
    store = dict(store)
    store["rts_hi"], store["rts_lo"] = eng.scatter_ts_max(
        ec, store["rts_hi"], store["rts_lo"], idx, ch, cl, ok_eff
    )

    partial = in_v & served.any(1) & ~final
    st["substep"] = jnp.where(partial, st["substep"] + 1, st["substep"])
    return StageOut(
        st, store, fail=in_v & bad.any(1), served_acc=served & final[:, None]
    )


def _lock_effect(ec, cm, wl, st, store, in_l, served, salt):
    """CAS lock + READ; require wts unchanged since fetch, then
    commit_tts = max(commit_tts, rts + 1)."""
    st = dict(st)
    won, store = eng.try_lock(
        ec,
        store,
        st,
        served,
        jnp.broadcast_to(st["ts_hi"][:, None], served.shape),
        jnp.broadcast_to(st["ts_lo"][:, None], served.shape),
    )
    st["locked"] = st["locked"] | won
    wts_now = _wts(ec, store, st["keys"])
    seen = TS(st["wts_seen_hi"], st["wts_seen_lo"])
    unchanged = ts_eq(wts_now, seen)
    lost = served & ~won
    fail = in_l & (lost.any(1) | (won & ~unchanged).any(1))
    rts_now = _rts(ec, store, st["keys"])
    st = _bump_commit(st, won, TS(rts_now.hi + 1, jnp.zeros_like(rts_now.lo)))
    ws = st["valid"] & st["is_w"]
    return StageOut(
        st,
        store,
        fail=fail,
        served_acc=jnp.zeros_like(served),
        outstanding=ws & ~st["locked"],
    )


def _fetch_effect(ec, cm, wl, st, store, in_f, served, salt):
    """Atomic tuple read; reads order after writers (commit_tts >= wts):
    tuple + version + wts ride one doorbell-batched plane round."""
    st = dict(st)
    got, ver = eng.read_rows_many(ec, (store["data"], store["ver"]), st["keys"])
    st["rvals"] = jnp.where(served[:, :, None], got, st["rvals"])
    st["ver_seen"] = jnp.where(served, ver, st["ver_seen"])
    wts_now = _wts(ec, store, st["keys"])
    st["wts_seen_hi"] = jnp.where(served, wts_now.hi, st["wts_seen_hi"])
    st["wts_seen_lo"] = jnp.where(served, wts_now.lo, st["wts_seen_lo"])
    rs = st["valid"] & ~st["is_w"]
    st = _bump_commit(st, served & rs, wts_now)
    return StageOut(st, store)


def _fresh_hook(st, fresh):
    st = dict(st)
    st["commit_hi"] = jnp.where(fresh, 0, st["commit_hi"])
    st["commit_lo"] = jnp.where(fresh, 0, st["commit_lo"])
    return st


SPECS = (
    StageSpec(
        stage=S_COMMIT,
        canon=ST_COMMIT,
        ops=rounds.ops_write_set,
        effect=_commit_effect,
        done="commit",
        salt_off=1,
        fuse_absorbs=ST_LOG,
    ),
    StageSpec(
        stage=S_ABREL,
        canon=ST_RELEASE,
        ops=rounds.ops_locked,
        effect=rounds.release_effect,
        done="abort",
        next_stage=S_FETCH,
        new_ts=True,
        salt_off=2,
    ),
    StageSpec(stage=S_LOG, canon=ST_LOG, kind=rounds.LOG, next_stage=S_COMMIT),
    StageSpec(
        stage=S_VALID,
        canon=ST_VALIDATE,
        ops=rounds.ops_read_set,
        effect=_validate_effect,
        next_stage=S_LOG,
        fuse_next=S_COMMIT,
        retry_stage=S_FETCH,
        abrel_stage=S_ABREL,
        new_ts=True,
        salt_off=3,
    ),
    StageSpec(
        stage=S_LOCKW,
        canon=ST_LOCK,
        ops=rounds.ops_lock_pending(write_only=True),
        effect=_lock_effect,
        next_stage=S_VALID,
        retry_stage=S_FETCH,
        abrel_stage=S_ABREL,
        new_ts=True,
        salt_off=4,
    ),
    StageSpec(stage=S_EXEC, canon=ST_EXEC, kind=rounds.EXEC, next_stage=S_LOCKW),
    StageSpec(
        stage=S_FETCH,
        canon=ST_FETCH,
        ops=rounds.ops_valid,
        effect=_fetch_effect,
        next_stage=S_EXEC,
        start_exec=True,
        salt_off=5,
    ),
)

tick = rounds.make_tick(specs=SPECS, start_stage=S_FETCH, salt_mult=43, fresh_hook=_fresh_hook)

STAGES_USED = ("fetch", "lock", "validate", "log", "commit", "release")

registry.register_protocol("sundial", tick=tick, stages=STAGES_USED, capabilities=registry.Caps())
