"""Latency/throughput cost model for the two communication planes.

The engine is a discrete-time bulk-synchronous simulator: one tick = one
network round for every in-flight transaction (the co-routine yields after
posting, exactly the paper's execution model).  Counts (rounds, bytes,
handler ops, aborts, waits) are *measured* from the simulated execution;
only the per-unit costs below are modeled, calibrated to EDR InfiniBand
microbenchmarks quoted in the paper's references [17,18,19,34]:

  * two-sided (RPC over UD): ~2.0-2.4 us RTT small msgs, plus remote CPU
    handler service time (the key scaling limit — Fig. 9).
  * one-sided READ/WRITE/CAS: ~1.6-2.0 us, no remote CPU, but LOCK+READ
    needs 2 dependent verbs unless doorbell-batched (§4.2), and NIC
    throughput degrades with QP count (Fig. 10).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax.numpy as jnp

RPC = 0
ONE_SIDED = 1

# canonical stage ids (superset across protocols).  The first six are
# network stages — the unit of hybridization (paper §5's binary coding);
# exec/wait are local buckets used only for the latency breakdown.
ST_FETCH, ST_LOCK, ST_VALIDATE, ST_LOG, ST_COMMIT, ST_RELEASE, ST_EXEC, ST_WAIT = range(8)
STAGE_NAMES = ("fetch", "lock", "validate", "log", "commit", "release", "exec", "wait")
N_HYBRID_STAGES = 6
N_STAGES = 8


@dataclass(frozen=True)
class CostModel:
    tick_us: float = 2.0  # one bulk-synchronous network round
    rpc_rtt_us: float = 2.2
    os_rtt_us: float = 1.8
    handler_us: float = 0.20  # remote CPU service time per RPC request
    # capacities calibrated so RPC saturates near the paper's co-routine
    # plateau (~10 handler threads x ~6 req/tick) while the RNIC's verb
    # rate sits ~8x higher (FaSST/DrTM+H microbenchmarks)
    handler_cap: int = 64  # RPC requests a node can service per tick
    nic_cap: int = 512  # one-sided verbs a node's RNIC serves per tick
    mmio_us: float = 0.15  # per-verb MMIO cost saved by doorbell batching
    byte_us: float = 0.00008  # ~12.5 GB/s per link
    n_backups: int = 3  # 3-way replication (paper §6.1)
    # per-run sweep knob — may hold a traced scalar inside a batched sweep
    # (see repro.core.sweep), so nic_eff_cap() must stay jnp-composable
    qp_pressure: float = 0.0  # grows with emulated cluster size (Fig. 10)

    def rtt(self, primitive: int) -> float:
        return self.rpc_rtt_us if primitive == RPC else self.os_rtt_us

    def nic_eff_cap(self):
        """NIC verb capacity degraded by QP-state cache pressure."""
        return self.nic_cap / (1.0 + self.qp_pressure)

    @staticmethod
    def tcp() -> "CostModel":
        """Reference TCP/kernel-stack plane (paper §1/§6: 'traditional
        TCP-based protocols'): ~10x RTT, syscall instead of MMIO, costlier
        handler service through the kernel network stack."""
        return CostModel(
            tick_us=18.0,
            rpc_rtt_us=25.0,
            os_rtt_us=25.0,  # no one-sided ops over TCP: both planes = sockets
            handler_us=1.5,
            handler_cap=12,
            nic_cap=12,
            mmio_us=2.0,  # syscall + copy
            byte_us=0.0008,  # ~1.25 GB/s effective
        )


# ---------------------------------------------------------------------------
# Wire-cost tables (DESIGN.md §5): the single source of truth for per-stage
# message bytes and verb counts.  Engine rounds, the stage-graph runtime
# (repro.core.rounds), CALVIN's epoch plane, and any analytical model must
# read these entries instead of scattering byte literals through protocol
# code — a stage's wire footprint is part of the protocol *specification*.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WireCost:
    """Wire bytes + verb count for one protocol stage's network round.

    ``bytes = base + words * 4 * rw + per_op * n_ops`` (rw = record words of
    4 bytes; n_ops = ops carried by one batch message), multiplied by the
    replication fan-out for stages that write the whole backup group.
    ``n_verbs`` is the number of one-sided verbs posted per request — >1
    means a dependent CAS+READ / WRITE+WRITE pair that doorbell batching
    (§4.2) collapses to a single MMIO.
    """

    base: float = 0.0
    words: float = 0.0
    per_op: float = 0.0
    n_verbs: int = 1
    replicated: bool = False

    def bytes_for(self, rw: int, n_backups: int = 1, n_ops: int = 1) -> float:
        b = self.base + self.words * 4.0 * rw + self.per_op * n_ops
        return b * (n_backups if self.replicated else 1)


# shared rows: every lock-based protocol logs coordinator-side to n_backups
# replicas and releases with a bare 8-byte unlock message.  COMMIT carries
# key + lock/seq metadata (12B) + the record payload for every protocol in
# the 2PL/OCC family (this table fixed a historical inconsistency where
# twopl charged 8B headers and occ 12B for the same message shape).
_LOG_WIRE = WireCost(base=8.0, words=1.0, replicated=True)
_RELEASE_WIRE = WireCost(base=8.0)
_COMMIT_WIRE = WireCost(base=12.0, words=1.0, n_verbs=2)

WIRE_COSTS: Dict[str, Dict[int, WireCost]] = {
    "twopl": {
        ST_LOCK: WireCost(base=16.0, words=1.0, n_verbs=2),  # CAS + READ doorbell
        ST_LOG: _LOG_WIRE,
        ST_COMMIT: _COMMIT_WIRE,
        ST_RELEASE: _RELEASE_WIRE,
    },
    "occ": {
        ST_FETCH: WireCost(base=12.0, words=1.0),  # speculative tuple+seq read
        ST_LOCK: WireCost(base=16.0, n_verbs=2),  # lock-only CAS + seq re-read
        ST_VALIDATE: WireCost(base=12.0),  # RS seq re-read
        ST_LOG: _LOG_WIRE,
        ST_COMMIT: _COMMIT_WIRE,
        ST_RELEASE: _RELEASE_WIRE,
    },
    "sundial": {
        ST_FETCH: WireCost(base=48.0, words=2.0, n_verbs=2),  # atomic double-read
        ST_LOCK: WireCost(base=24.0, words=1.0, n_verbs=2),  # CAS + READ (wts check)
        ST_VALIDATE: WireCost(base=24.0),  # lease renewal read/CAS
        ST_LOG: _LOG_WIRE,
        ST_COMMIT: WireCost(base=16.0, words=1.0, n_verbs=2),  # wts|rts + record
        ST_RELEASE: _RELEASE_WIRE,
    },
    "mvcc": {
        # double-read of the full 4-slot version array (paper §4.4 static
        # slots; the wire table pins the paper's 4 even under the
        # mvcc_slots ablation knob so codings stay byte-comparable)
        ST_FETCH: WireCost(base=48.0, words=8.0, n_verbs=2),
        ST_LOCK: WireCost(base=24.0, words=1.0, n_verbs=2),  # CAS tts + READ
        ST_VALIDATE: WireCost(base=16.0),  # validated rts CAS-max
        ST_LOG: _LOG_WIRE,
        ST_COMMIT: WireCost(base=16.0, words=1.0, n_verbs=2),  # oldest-slot write
        ST_RELEASE: _RELEASE_WIRE,
    },
}

# CALVIN's epoch plane (sequencing broadcast + RS/WS forwarding) is not a
# slot-engine stage machine, but its message shapes live in the same table.
CALVIN_WIRE: Dict[str, WireCost] = {
    "sequence": WireCost(base=16.0, per_op=5.0, n_verbs=2),  # txn descriptor batch
    "forward": WireCost(base=8.0, words=1.0, n_verbs=2),  # RS/WS record ship
}

_PROTO_FAMILY = {"nowait": "twopl", "waitdie": "twopl"}


def wire_cost(protocol: str, stage: int) -> WireCost:
    """Wire-cost entry for a protocol's canonical stage (family-aliased).

    The registry's ``family`` key resolves first, so plugin protocols that
    registered with ``family=<builtin>`` inherit its wire table without
    editing WIRE_COSTS; the static alias map keeps the table usable for
    unregistered names.
    """
    from repro.core import registry

    fam = registry.protocol_family(protocol)
    return WIRE_COSTS[_PROTO_FAMILY.get(fam, fam)][stage]


def queue_delay_us(cm: CostModel, primitive_is_rpc, dest_load):
    """Queueing delay at the destination given this tick's load (per request).

    dest_load: number of same-plane requests arriving at the destination node
    this tick.  RPC requests queue on the handler CPU; one-sided verbs queue
    on the RNIC (much higher capacity, no CPU involvement).
    """
    rpc_delay = cm.handler_us * jnp.maximum(dest_load - 1, 0.0) / 2.0
    rpc_delay = rpc_delay + cm.handler_us
    nic_unit = 1.0 / jnp.maximum(jnp.asarray(cm.nic_eff_cap(), jnp.float32), 1e-6) * cm.tick_us
    nic_delay = nic_unit * jnp.maximum(dest_load - 1, 0.0) / 2.0
    return jnp.where(primitive_is_rpc, rpc_delay, nic_delay)


def round_latency_us(cm: CostModel, primitive_is_rpc, dest_load, msg_bytes, n_verbs=1, doorbell=True):
    """Latency of one network round for a request batch of n_verbs verbs."""
    base = jnp.where(primitive_is_rpc, cm.rpc_rtt_us, cm.os_rtt_us)
    mmio = jnp.where(
        primitive_is_rpc,
        cm.mmio_us,
        cm.mmio_us * (1 if doorbell else n_verbs),
    )
    wire = msg_bytes * cm.byte_us
    return base + mmio + wire + queue_delay_us(cm, primitive_is_rpc, dest_load)
