"""Latency/throughput cost model for the two communication planes.

The engine is a discrete-time bulk-synchronous simulator: one tick = one
network round for every in-flight transaction (the co-routine yields after
posting, exactly the paper's execution model).  Counts (rounds, bytes,
handler ops, aborts, waits) are *measured* from the simulated execution;
only the per-unit costs below are modeled, calibrated to EDR InfiniBand
microbenchmarks quoted in the paper's references [17,18,19,34]:

  * two-sided (RPC over UD): ~2.0-2.4 us RTT small msgs, plus remote CPU
    handler service time (the key scaling limit — Fig. 9).
  * one-sided READ/WRITE/CAS: ~1.6-2.0 us, no remote CPU, but LOCK+READ
    needs 2 dependent verbs unless doorbell-batched (§4.2), and NIC
    throughput degrades with QP count (Fig. 10).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax.numpy as jnp

RPC = 0
ONE_SIDED = 1

# canonical stage ids (superset across protocols).  The first six are
# network stages — the unit of hybridization (paper §5's binary coding);
# exec/wait are local buckets used only for the latency breakdown.
ST_FETCH, ST_LOCK, ST_VALIDATE, ST_LOG, ST_COMMIT, ST_RELEASE, ST_EXEC, ST_WAIT = range(8)
STAGE_NAMES = ("fetch", "lock", "validate", "log", "commit", "release", "exec", "wait")
N_HYBRID_STAGES = 6
N_STAGES = 8


@dataclass(frozen=True)
class CostModel:
    tick_us: float = 2.0  # one bulk-synchronous network round
    rpc_rtt_us: float = 2.2
    os_rtt_us: float = 1.8
    handler_us: float = 0.20  # remote CPU service time per RPC request
    # capacities calibrated so RPC saturates near the paper's co-routine
    # plateau (~10 handler threads x ~6 req/tick) while the RNIC's verb
    # rate sits ~8x higher (FaSST/DrTM+H microbenchmarks)
    handler_cap: int = 64  # RPC requests a node can service per tick
    nic_cap: int = 512  # one-sided verbs a node's RNIC serves per tick
    mmio_us: float = 0.15  # per-verb MMIO cost saved by doorbell batching
    byte_us: float = 0.00008  # ~12.5 GB/s per link
    n_backups: int = 3  # 3-way replication (paper §6.1)
    # per-run sweep knob — may hold a traced scalar inside a batched sweep
    # (see repro.core.sweep), so nic_eff_cap() must stay jnp-composable
    qp_pressure: float = 0.0  # grows with emulated cluster size (Fig. 10)

    def rtt(self, primitive: int) -> float:
        return self.rpc_rtt_us if primitive == RPC else self.os_rtt_us

    def nic_eff_cap(self):
        """NIC verb capacity degraded by QP-state cache pressure."""
        return self.nic_cap / (1.0 + self.qp_pressure)

    @staticmethod
    def tcp() -> "CostModel":
        """Reference TCP/kernel-stack plane (paper §1/§6: 'traditional
        TCP-based protocols'): ~10x RTT, syscall instead of MMIO, costlier
        handler service through the kernel network stack."""
        return CostModel(
            tick_us=18.0,
            rpc_rtt_us=25.0,
            os_rtt_us=25.0,  # no one-sided ops over TCP: both planes = sockets
            handler_us=1.5,
            handler_cap=12,
            nic_cap=12,
            mmio_us=2.0,  # syscall + copy
            byte_us=0.0008,  # ~1.25 GB/s effective
        )


def queue_delay_us(cm: CostModel, primitive_is_rpc, dest_load):
    """Queueing delay at the destination given this tick's load (per request).

    dest_load: number of same-plane requests arriving at the destination node
    this tick.  RPC requests queue on the handler CPU; one-sided verbs queue
    on the RNIC (much higher capacity, no CPU involvement).
    """
    rpc_delay = cm.handler_us * jnp.maximum(dest_load - 1, 0.0) / 2.0
    rpc_delay = rpc_delay + cm.handler_us
    nic_unit = 1.0 / jnp.maximum(jnp.asarray(cm.nic_eff_cap(), jnp.float32), 1e-6) * cm.tick_us
    nic_delay = nic_unit * jnp.maximum(dest_load - 1, 0.0) / 2.0
    return jnp.where(primitive_is_rpc, rpc_delay, nic_delay)


def round_latency_us(cm: CostModel, primitive_is_rpc, dest_load, msg_bytes, n_verbs=1, doorbell=True):
    """Latency of one network round for a request batch of n_verbs verbs."""
    base = jnp.where(primitive_is_rpc, cm.rpc_rtt_us, cm.os_rtt_us)
    mmio = jnp.where(
        primitive_is_rpc,
        cm.mmio_us,
        cm.mmio_us * (1 if doorbell else n_verbs),
    )
    wire = msg_bytes * cm.byte_us
    return base + mmio + wire + queue_delay_us(cm, primitive_is_rpc, dest_load)
