"""Serializability validation of committed histories.

Builds the version-order precedence graph (WW / WR / RW edges per record)
from the engine's commit history and checks acyclicity — the standard
conflict-serializability test.  Also provides store-consistency invariants
(no lost updates: final version counters and read-modify-write chains must
match the committed write counts).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import networkx as nx
import numpy as np


def extract_history(st: Dict) -> List[dict]:
    n = int(np.asarray(st["h_idx"])[0])
    n = min(n, st["h_keys"].shape[0])
    out = []
    for i in range(n):
        ops = []
        for j in range(st["h_keys"].shape[1]):
            if not bool(st["h_valid"][i, j]):
                continue
            ops.append(
                dict(
                    key=int(st["h_keys"][i, j]),
                    ver_r=int(st["h_ver_r"][i, j]),
                    ver_w=int(st["h_ver_w"][i, j]),
                    is_w=bool(st["h_isw"][i, j]),
                )
            )
        out.append(dict(txn=i, ts=(int(st["h_ts_hi"][i]), int(st["h_ts_lo"][i])), ops=ops))
    return out


def precedence_graph(history: List[dict]) -> nx.DiGraph:
    g = nx.DiGraph()
    g.add_nodes_from(t["txn"] for t in history)
    # per key: writers by produced version; readers by version read
    writers: Dict[Tuple[int, int], int] = {}
    readers: Dict[int, List[Tuple[int, int]]] = {}
    key_writes: Dict[int, List[int]] = {}
    for t in history:
        for op in t["ops"]:
            if op["is_w"]:
                writers[(op["key"], op["ver_w"])] = t["txn"]
                key_writes.setdefault(op["key"], []).append(op["ver_w"])
            readers.setdefault(op["key"], []).append((op["ver_r"], t["txn"]))
    for key, vers in key_writes.items():
        vs = sorted(set(vers))
        # WW edges along the version chain
        for a, b in zip(vs, vs[1:]):
            g.add_edge(writers[(key, a)], writers[(key, b)])
        nxt = {a: b for a, b in zip(vs, vs[1:])}
        for ver_r, txn in readers.get(key, []):
            w = writers.get((key, ver_r))
            if w is not None and w != txn:
                g.add_edge(w, txn)  # WR: read version's writer precedes reader
            nv = nxt.get(ver_r)
            if nv is None:
                # first write after ver_r (reader of a non-boundary version)
                later = [v for v in vs if v > ver_r]
                nv = later[0] if later else None
            if nv is not None and writers[(key, nv)] != txn:
                g.add_edge(txn, writers[(key, nv)])  # RW: reader precedes next writer
    return g


def is_serializable(history: List[dict]) -> Tuple[bool, List]:
    g = precedence_graph(history)
    try:
        cycle = nx.find_cycle(g)
        return False, cycle
    except nx.NetworkXNoCycle:
        return True, []


def check_no_lost_updates(history: List[dict], store: Dict) -> Tuple[bool, str]:
    """Final per-key version counter must equal committed write count
    (every committed write produced a distinct, persisted version)."""
    writes: Dict[int, int] = {}
    vers: Dict[int, set] = {}
    for t in history:
        for op in t["ops"]:
            if op["is_w"]:
                writes[op["key"]] = writes.get(op["key"], 0) + 1
                vers.setdefault(op["key"], set()).add(op["ver_w"])
    ver = np.asarray(store["ver"])
    for key, cnt in writes.items():
        if len(vers[key]) != cnt:
            return False, f"key {key}: {cnt} commits produced {len(vers[key])} versions (lost update)"
        if ver[key] < max(vers[key]):
            return False, f"key {key}: store version {ver[key]} < max committed {max(vers[key])}"
    return True, ""
