"""Serializability validation of committed histories.

Builds the version-order precedence graph (WW / WR / RW edges per record)
from the engine's commit history and checks acyclicity — the standard
conflict-serializability test.  Also provides store-consistency invariants
(no lost updates: final version counters and read-modify-write chains must
match the committed write counts) and the cross-protocol SERIALIZABILITY
ORACLE: :func:`replay_committed` re-executes the committed history in
commit order against a plain sequential store, and :func:`final_data`
projects a protocol store down to its latest committed record values, so
``replay == final_data`` asserts final-state equivalence for every engine
protocol under one test (tests/test_oracle.py).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import networkx as nx
import numpy as np


def extract_history(st: Dict) -> List[dict]:
    n = int(np.asarray(st["h_idx"])[0])
    n = min(n, st["h_keys"].shape[0])
    out = []
    for i in range(n):
        ops = []
        for j in range(st["h_keys"].shape[1]):
            if not bool(st["h_valid"][i, j]):
                continue
            ops.append(
                dict(
                    key=int(st["h_keys"][i, j]),
                    ver_r=int(st["h_ver_r"][i, j]),
                    ver_w=int(st["h_ver_w"][i, j]),
                    is_w=bool(st["h_isw"][i, j]),
                )
            )
        out.append(dict(txn=i, ts=(int(st["h_ts_hi"][i]), int(st["h_ts_lo"][i])), ops=ops))
    return out


def precedence_graph(history: List[dict]) -> nx.DiGraph:
    g = nx.DiGraph()
    g.add_nodes_from(t["txn"] for t in history)
    # per key: writers by produced version; readers by version read
    writers: Dict[Tuple[int, int], int] = {}
    readers: Dict[int, List[Tuple[int, int]]] = {}
    key_writes: Dict[int, List[int]] = {}
    for t in history:
        for op in t["ops"]:
            if op["is_w"]:
                writers[(op["key"], op["ver_w"])] = t["txn"]
                key_writes.setdefault(op["key"], []).append(op["ver_w"])
            readers.setdefault(op["key"], []).append((op["ver_r"], t["txn"]))
    for key, vers in key_writes.items():
        vs = sorted(set(vers))
        # WW edges along the version chain
        for a, b in zip(vs, vs[1:]):
            g.add_edge(writers[(key, a)], writers[(key, b)])
        nxt = {a: b for a, b in zip(vs, vs[1:])}
        for ver_r, txn in readers.get(key, []):
            w = writers.get((key, ver_r))
            if w is not None and w != txn:
                g.add_edge(w, txn)  # WR: read version's writer precedes reader
            nv = nxt.get(ver_r)
            if nv is None:
                # first write after ver_r (reader of a non-boundary version)
                later = [v for v in vs if v > ver_r]
                nv = later[0] if later else None
            if nv is not None and writers[(key, nv)] != txn:
                g.add_edge(txn, writers[(key, nv)])  # RW: reader precedes next writer
    return g


def is_serializable(history: List[dict]) -> Tuple[bool, List]:
    g = precedence_graph(history)
    try:
        cycle = nx.find_cycle(g)
        return False, cycle
    except nx.NetworkXNoCycle:
        return True, []


def final_data(store: Dict) -> np.ndarray:
    """Latest committed record values (R, rw), protocol-layout-agnostic.

    Single-version stores expose ``data`` directly; MVCC's latest version
    is the slot with the lexicographically largest wts (slot 0 is seeded as
    the initial committed version, so fresh records resolve to it).
    """
    if "vdata" not in store:
        return np.asarray(store["data"])
    wts_hi = np.asarray(store["wts_hi"])
    wts_lo = np.asarray(store["wts_lo"])
    best_hi = wts_hi.max(axis=1, keepdims=True)
    lo_masked = np.where(wts_hi == best_hi, wts_lo, np.int32(-(2**31)))
    best = lo_masked.argmax(axis=1)
    return np.asarray(store["vdata"])[np.arange(wts_hi.shape[0]), best]


def replay_committed(st: Dict, wl, n_records: int) -> np.ndarray:
    """Replay the committed history in commit order on a sequential store.

    Each committed transaction reads its operands from the sequential
    store, re-runs the workload's ``execute`` and writes back its write
    set — the textbook serial execution.  If the protocol's interleaved
    run was serializable in its commit order, the resulting store matches
    :func:`final_data` of the engine's store exactly (the oracle).
    """
    n = int(np.asarray(st["h_idx"])[0])
    cap = st["h_keys"].shape[0]
    if n > cap:
        raise ValueError(f"history overflowed: {n} commits > history_cap {cap}")
    keys = jnp.asarray(st["h_keys"])[:n]
    is_w = jnp.asarray(st["h_isw"])[:n]
    valid = jnp.asarray(st["h_valid"])[:n]
    data0 = jnp.full((n_records, wl.rw), wl.init_value, jnp.int32)
    if n == 0:
        return np.asarray(data0)

    def step(data, row):
        k, w, v = row
        wv = wl.execute(k, w, v, data[k])
        eff = w & v
        data = data.at[jnp.where(eff, k, n_records)].set(wv, mode="drop")
        return data, None

    data, _ = jax.jit(lambda d, rows: jax.lax.scan(step, d, rows))(data0, (keys, is_w, valid))
    return np.asarray(data)


def inflight_commit_writes(st: Dict, commit_stage: int) -> np.ndarray:
    """Keys partially written by transactions caught mid-COMMIT at run end.

    A commit round can straddle ticks under capacity limits: its served
    write ops have already hit the store while the transaction is not yet
    counted committed (no history row).  The oracle excludes these keys
    from the final-state comparison.
    """
    in_c = np.asarray(st["stage"]) == commit_stage
    written = np.asarray(st["served"]) & np.asarray(st["is_w"]) & np.asarray(st["valid"])
    return np.unique(np.asarray(st["keys"])[in_c[:, None] & written])


def check_no_lost_updates(history: List[dict], store: Dict) -> Tuple[bool, str]:
    """Final per-key version counter must equal committed write count
    (every committed write produced a distinct, persisted version)."""
    writes: Dict[int, int] = {}
    vers: Dict[int, set] = {}
    for t in history:
        for op in t["ops"]:
            if op["is_w"]:
                writes[op["key"]] = writes.get(op["key"], 0) + 1
                vers.setdefault(op["key"], set()).add(op["ver_w"])
    ver = np.asarray(store["ver"])
    for key, cnt in writes.items():
        if len(vers[key]) != cnt:
            return False, f"key {key}: {cnt} commits produced {len(vers[key])} versions (lost update)"
        if ver[key] < max(vers[key]):
            return False, f"key {key}: store version {ver[key]} < max committed {max(vers[key])}"
    return True, ""
