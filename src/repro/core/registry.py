"""Protocol plugin registry: the open counterpart to the old ``PROTOCOLS`` dict.

RCC's goal is to be "the common infrastructure for fast prototyping new
implementations" (PAPER.md).  Before this module, adding a protocol meant
editing a closed dict in ``protocols/__init__`` *and* chasing
``if protocol == "calvin"`` branches through the sweep engine.  Now a
protocol is one module plus one call:

    from repro.core import registry

    registry.register_protocol(
        "myproto",
        tick=rounds.make_tick(specs=MY_SPECS, start_stage=S0, salt_mult=53),
        stages=("fetch", "lock", "commit", "release"),
        capabilities=registry.Caps(node_shardable=True),
    )

and every front-door surface — ``repro.api.plan/execute``, the benchmarks,
the dev-smoke protocol matrix — picks it up by name.  Planner decisions
(which mesh layouts a protocol supports, whether it runs the slot engine
or its own epoch loop) are driven by the entry's :class:`Caps` and
:class:`RunHooks` instead of name comparisons scattered through sweep.py.

The six built-ins register themselves when ``repro.core.protocols`` is
imported; :func:`get_protocol` triggers that import lazily so callers never
need to know the load order.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Mapping, NamedTuple, Optional, Tuple


class Caps(NamedTuple):
    """Capability flags consumed by the ``repro.api`` planner.

    ``node_shardable``   — the protocol can run one config with the simulated
                           ``n_nodes`` axis SPMD on a device mesh (the
                           ``node`` layout, DESIGN.md §7).
    ``batch_node_shardable`` — configs can additionally be *batched around*
                           the node collectives on a 2-D ``config × node``
                           mesh.  CALVIN sets this False: its wave executor
                           iterates a per-config traced wave count, which
                           cannot vmap around the collective loop.
    ``deterministic``    — committed work is independent of arbitration
                           order (CALVIN's node-permutation determinism).
    ``ro_commit``        — the protocol declares a read-only commit fast
                           path (StageSpec.ro_commit) somewhere in its table.
    ``tick_driven``      — runs the slot engine (``tick`` compiled from a
                           StageSpec table).  False = the protocol owns its
                           loop via custom :class:`RunHooks` (CALVIN epochs).
    """

    node_shardable: bool = True
    batch_node_shardable: bool = True
    deterministic: bool = False
    ro_commit: bool = False
    tick_driven: bool = True


class RunHooks(NamedTuple):
    """How the sweep engine obtains metrics for one engine configuration.

    Both hooks receive the registered :class:`ProtocolEntry` first, then the
    fully-built ``(ec, cm, wl)`` triple; every knob inside may be traced
    (the batched sweep vmaps over them), so hooks must not Python-branch on
    knob values.

    ``grid_run(entry, ec, cm, wl, *, ticks, warmup, ticks_active)`` —
        one dense (or vmapped / shard_map-wrapped) run; returns the metrics
        dict (``engine.summarize`` schema).
    ``node_run(entry, ec, cm, wl, *, ticks, warmup, devices)`` —
        one config with the ``n_nodes`` axis SPMD over ``devices``; returns
        the same metrics schema.
    """

    grid_run: Callable[..., Dict]
    node_run: Callable[..., Dict]


def _default_grid_run(entry: "ProtocolEntry", ec, cm, wl, *, ticks, warmup, ticks_active):
    from repro.core.engine import run

    _, _, m = run(entry.tick, ec, cm, wl, ticks, warmup=warmup, ticks_active=ticks_active)
    return m


def _default_node_run(entry: "ProtocolEntry", ec, cm, wl, *, ticks, warmup, devices):
    from repro.core.engine import run_sharded

    _, _, m = run_sharded(entry.tick, ec, cm, wl, ticks, warmup=warmup, devices=devices)
    return m


DEFAULT_HOOKS = RunHooks(grid_run=_default_grid_run, node_run=_default_node_run)


class ProtocolEntry(NamedTuple):
    """One registered protocol: everything the planner/engine needs by name."""

    name: str
    tick: Optional[Callable]  # slot-engine tick; None for epoch-driven protocols
    stages: Tuple[str, ...]  # canonical stage names the protocol exercises
    caps: Caps
    hooks: RunHooks
    variant: Mapping[str, Any]  # e.g. {"wait_die": True} for the 2PL family
    # runtime-profile key for the name-keyed engine tables: store layout
    # (store.init_store), wire costs (costmodel.WIRE_COSTS) and doorbell
    # merge pairs (rounds.MERGE_TABLE).  A plugin that reuses an existing
    # protocol's data layout registers with family=<that protocol> and gets
    # identical store/wire semantics without touching those tables.
    family: str = ""


_REGISTRY: Dict[str, ProtocolEntry] = {}


def register_protocol(
    name: str,
    *,
    tick: Optional[Callable] = None,
    stages: Tuple[str, ...] = (),
    hooks: Optional[RunHooks] = None,
    capabilities: Caps = Caps(),
    variant: Optional[Mapping[str, Any]] = None,
    family: Optional[str] = None,
    override: bool = False,
) -> ProtocolEntry:
    """Register a protocol under ``name``; returns the stored entry.

    ``tick`` is required for tick-driven protocols (``capabilities.tick_driven``);
    epoch-driven protocols pass ``tick=None`` and custom ``hooks`` instead.
    ``family`` (default: the protocol's own name) keys the engine's runtime
    tables — store layout, wire costs, merge pairs — so variants of an
    existing protocol inherit its data layout (NOWAIT/WAITDIE register with
    ``family="twopl"``).  Re-registering an existing name raises unless
    ``override=True`` (call ``unregister_protocol(name)`` first, or pass
    ``override=True``, to replace a built-in on purpose).
    """
    if not name or not isinstance(name, str):
        raise ValueError(f"register_protocol: protocol name must be a non-empty str, got {name!r}")
    if name in _REGISTRY and not override:
        raise ValueError(
            f"protocol {name!r} is already registered; pass "
            f"register_protocol({name!r}, ..., override=True) to replace it or "
            f"unregister_protocol({name!r}) first"
        )
    if capabilities.tick_driven and tick is None:
        raise ValueError(
            f"register_protocol({name!r}): tick-driven protocols need a compiled tick "
            "(rounds.make_tick over a StageSpec table); epoch-driven protocols must set "
            "capabilities=Caps(tick_driven=False) and provide custom RunHooks"
        )
    if not capabilities.tick_driven and hooks is None:
        raise ValueError(
            f"register_protocol({name!r}): Caps(tick_driven=False) protocols own their "
            "run loop — provide RunHooks(grid_run=..., node_run=...)"
        )
    entry = ProtocolEntry(
        name=name,
        tick=tick,
        stages=tuple(stages),
        caps=capabilities,
        hooks=hooks if hooks is not None else DEFAULT_HOOKS,
        variant=dict(variant or {}),
        family=family if family is not None else name,
    )
    _REGISTRY[name] = entry
    return entry


def unregister_protocol(name: str) -> None:
    """Remove a registered protocol (test/plugin hygiene)."""
    _ensure_builtins()
    if name not in _REGISTRY:
        raise KeyError(
            f"unregister_protocol: unknown protocol {name!r}; registered: {protocol_names()}"
        )
    del _REGISTRY[name]


def _ensure_builtins() -> None:
    # the six built-ins self-register when their modules load; importing the
    # package is idempotent and cheap after the first time
    import repro.core.protocols  # noqa: F401


def get_protocol(name: str) -> ProtocolEntry:
    """Look up a registered protocol by name (actionable KeyError if absent)."""
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown protocol {name!r}; registered protocols: {protocol_names()}. "
            "Add new ones via repro.core.registry.register_protocol(name, tick=..., "
            "stages=..., capabilities=Caps(...))"
        ) from None


def protocol_names() -> Tuple[str, ...]:
    """Registered protocol names, in registration order."""
    _ensure_builtins()
    return tuple(_REGISTRY)


def protocol_family(name: str) -> str:
    """Runtime-profile key for ``name`` (store layout / wire costs / merge
    pairs).  Unregistered names resolve to themselves so the low-level
    engine tables keep working standalone."""
    _ensure_builtins()
    entry = _REGISTRY.get(name)
    return entry.family if entry is not None else name


class ProtocolsView(Mapping):
    """Read-only live view of the registry, keeping the historical
    ``PROTOCOLS[name].tick`` shape working (entries expose ``.tick``)."""

    def __getitem__(self, name: str) -> ProtocolEntry:
        return get_protocol(name)

    def __iter__(self):
        return iter(protocol_names())

    def __len__(self) -> int:
        _ensure_builtins()
        return len(_REGISTRY)

    def __contains__(self, name) -> bool:
        _ensure_builtins()
        return name in _REGISTRY

    def __repr__(self) -> str:
        return f"ProtocolsView({protocol_names()})"
