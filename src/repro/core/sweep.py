"""Batched sweep engine: a grid of engine configurations as ONE program.

This is the ENGINE ROOM of the ``repro.api`` front door (DESIGN.md §8):
``api.plan`` consumes :func:`plan_buckets`, ``api.execute`` dispatches to
the jitted entry points below, and protocols are resolved through
``repro.core.registry`` (epoch-driven protocols bring their own RunHooks —
no protocol-name branches here).  The historical entry points
(:func:`run_grid`, :func:`run_grid_sharded`, :func:`run_cell_sharded`)
survive as thin deprecation shims that delegate to ``plan``/``execute``.

The paper's central experiment is an unbiased sweep over {protocol} x
{2^6 hybrid stage codings} x workload knobs.  Running each cell through a
fresh ``jax.jit`` costs one XLA compilation per cell — the exhaustive
hybrid enumeration alone is 64 compiles.  This module splits a run's
parameters into

  * a static :class:`GridSpec` (shapes + protocol + tick counts): one
    compilation per distinct spec, cached on the jitted entry point; and
  * traced :class:`RunKnobs` (hybrid coding as an int32[N_HYBRID_STAGES]
    array, seed, exec_ticks, hot_prob, qp_pressure): vmapped, so a whole
    grid of knob settings shares the single compiled ``lax.scan``.

``run_grid`` is the public API: it stacks the per-config knobs, runs
``vmap(run)`` under one jit, and unstacks the metrics into per-config
dicts shaped like ``benchmarks.common.run_cell``'s output.

Two scale-out layers sit on top (DESIGN.md §6):

  * **Bucketed static-axis padding**: configs may sweep the two static
    shape axes (``coroutines``, ``records_per_node``).  ``plan_buckets``
    groups configs into power-of-two shape buckets, pads each bucket to
    its max shape, and threads the per-config ACTIVE extents through as
    traced knobs (``EngineConfig.active_*``) — one XLA compile per bucket
    instead of one per distinct shape, with padded slots/records provably
    inert (bitwise-equal counters to the unpadded run).
  * **Device sharding**: ``run_grid_sharded`` splits the config axis over
    ``jax.sharding`` (a 1-D ``grid`` mesh).  Grids that don't divide the
    device count are remainder-padded (the pad rows replicate the last
    config and are dropped on output), so any grid size works on any
    device count — real devices or ``--xla_force_host_platform_device_count``
    fake hosts — with output bitwise-equal to the single-device path.
"""
from __future__ import annotations

import functools
import itertools
import warnings
from typing import Any, Dict, Iterable, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core import registry
from repro.core.costmodel import N_HYBRID_STAGES, RPC, CostModel
from repro.core.engine import EngineConfig
from repro.workloads import make_workload

# Per-workload knob defaults, mirroring each factory's signature; resolved
# at grid-construction (Python) time so an unspecified knob reproduces the
# sequential run_cell exactly.
WL_EXEC_TICKS = {"smallbank": 1, "ycsb": 3, "tpcc": 5}
YCSB_HOT_PROB = 0.10

KNOB_KEYS = ("hybrid", "seed", "exec_ticks", "hot_prob", "qp_pressure")

# static shape axes that plan_buckets can turn into traced active-extent
# knobs (per-config values in run_grid's ``configs`` dicts).  ``ticks`` is
# the scan-length axis: padded to the bucket max and early-exited per
# config (dead ticks freeze the carry and touch no counter), so a ticks
# sweep compiles once per bucket instead of once per distinct length.
STATIC_AXES = ("coroutines", "records_per_node", "ticks")


class GridSpec(NamedTuple):
    """Static shape/compile params — one XLA compilation per distinct value."""

    protocol: str
    workload: str
    n_nodes: int = 4
    coroutines: int = 60
    records_per_node: int = 65536
    ticks: int = 400
    warmup: int = 80
    history_cap: int = 0
    mvcc_slots: int = 4
    doorbell: bool = True
    tcp: bool = False
    merge_stages: bool = False  # cross-stage doorbell merging (rounds.py §4.2)
    kernel_plane: str = "jnp"  # fused hot-path backend (kernels/ops.py, DESIGN.md §9)


class RunKnobs(NamedTuple):
    """Traced per-run knobs; in ``run_grid`` every leaf has a leading grid axis.

    ``coroutines_active`` / ``records_active`` are the bucket-padding active
    extents (int32[...]) — None (an empty pytree leaf) when the matching
    static axis is unpadded, which keeps the legacy knob-only grids on the
    exact pre-bucketing program (pinned golden counters cannot drift).
    """

    hybrid: Any  # int32[..., N_HYBRID_STAGES]
    seed: Any  # int32[...]
    exec_ticks: Any  # int32[...]
    hot_prob: Any  # float32[...]
    qp_pressure: Any  # float32[...]
    coroutines_active: Any = None  # int32[...] live co-routines per node
    records_active: Any = None  # int32[...] live records per node
    ticks_active: Any = None  # int32[...] live measured ticks (tick bucketing)


def normalize_hybrid(code) -> Tuple[int, ...]:
    """Hybrid coding as a stage tuple; ints are bitmasks (bit i = stage i)."""
    if isinstance(code, (int, np.integer)):
        return tuple((int(code) >> i) & 1 for i in range(N_HYBRID_STAGES))
    code = tuple(int(b) for b in code)
    if len(code) != N_HYBRID_STAGES:
        raise ValueError(f"hybrid coding needs {N_HYBRID_STAGES} stages, got {code}")
    return code


def all_hybrid_codes() -> List[Tuple[int, ...]]:
    """All 2^N_HYBRID_STAGES stage codings (the paper's exhaustive sweep)."""
    return [normalize_hybrid(i) for i in range(2**N_HYBRID_STAGES)]


def grid_product(**axes: Sequence) -> List[Dict]:
    """Cartesian product of named knob axes -> list of config dicts."""
    names = list(axes)
    return [dict(zip(names, vals)) for vals in itertools.product(*(axes[n] for n in names))]


def make_knobs(workload: str, configs: Iterable[Dict]) -> RunKnobs:
    """Stack per-config knob dicts into a batched RunKnobs pytree.

    Each config may set any of ``hybrid`` (tuple or int bitmask), ``seed``,
    ``exec_ticks``, ``hot_prob``, ``qp_pressure``; omitted knobs take the
    workload's defaults.
    """
    configs = list(configs)
    if not configs:
        raise ValueError("empty config grid: pass at least one knob dict")
    rows = []
    for c in configs:
        c = dict(c)
        hy = normalize_hybrid(c.pop("hybrid", (RPC,) * N_HYBRID_STAGES))
        seed = int(c.pop("seed", 0))
        et = c.pop("exec_ticks", None)
        et = WL_EXEC_TICKS.get(workload, 1) if et is None else int(et)
        hp = c.pop("hot_prob", None)
        if hp is not None and workload != "ycsb":
            raise TypeError(f"hot_prob is a ycsb-only knob; workload={workload!r}")
        hp = YCSB_HOT_PROB if hp is None else float(hp)
        qp = float(c.pop("qp_pressure", 0.0))
        if c:
            raise TypeError(f"unknown knob(s): {sorted(c)}; valid: {KNOB_KEYS}")
        rows.append((hy, seed, et, hp, qp))
    hy, seed, et, hp, qp = zip(*rows)
    return RunKnobs(
        hybrid=jnp.asarray(np.array(hy, np.int32)),
        seed=jnp.asarray(np.array(seed, np.int32)),
        exec_ticks=jnp.asarray(np.array(et, np.int32)),
        hot_prob=jnp.asarray(np.array(hp, np.float32)),
        qp_pressure=jnp.asarray(np.array(qp, np.float32)),
    )


def _run_one(spec: GridSpec, kn: RunKnobs, shard=None) -> Dict:
    """One engine run with traced knobs (vmapped over the grid axis).

    ``shard`` (a ``planes.NodeShard``) runs the engine node-sharded: only
    meaningful inside a ``shard_map`` over that mesh axis (the 2-D
    ``config × node`` grid dispatch below).
    """
    cm = CostModel.tcp() if spec.tcp else CostModel(qp_pressure=kn.qp_pressure)
    # bucket padding: the workload draws over the LOGICAL (active) record
    # space; the engine owns the padded physical layout
    rpn = spec.records_per_node if kn.records_active is None else kn.records_active
    n_records = spec.n_nodes * rpn
    wkw: Dict[str, Any] = {"exec_ticks": kn.exec_ticks}
    if spec.workload == "ycsb":
        wkw["hot_prob"] = kn.hot_prob
    wl = make_workload(spec.workload, n_records, **wkw)
    ec = EngineConfig(
        protocol=spec.protocol,
        n_nodes=spec.n_nodes,
        coroutines=spec.coroutines,
        records_per_node=spec.records_per_node,
        active_coroutines=kn.coroutines_active,
        active_records_per_node=kn.records_active,
        rw=wl.rw,
        max_ops=wl.max_ops,
        hybrid=kn.hybrid,
        doorbell=spec.doorbell,
        merge_stages=spec.merge_stages,
        exec_ticks=kn.exec_ticks,
        history_cap=spec.history_cap,
        mvcc_slots=spec.mvcc_slots,
        seed=kn.seed,
        kernel_plane=spec.kernel_plane,
        shard=shard,
    )
    entry = registry.get_protocol(spec.protocol)
    # epoch-vs-tick dispatch lives in the registry entry's hooks, not in
    # name comparisons here: a new protocol brings its own runner if needed
    return entry.hooks.grid_run(
        entry, ec, cm, wl,
        ticks=spec.ticks, warmup=spec.warmup, ticks_active=kn.ticks_active,
    )


@functools.partial(jax.jit, static_argnums=0)
def _run_grid_jit(spec: GridSpec, knobs: RunKnobs) -> Dict:
    return jax.vmap(functools.partial(_run_one, spec))(knobs)


@functools.partial(jax.jit, static_argnums=0)
def _run_grid_sharded_jit(spec: GridSpec, knobs: RunKnobs) -> Dict:
    # identical program to _run_grid_jit; a separate jit entry so the two
    # compile counters stay independent (the sharded path recompiles per
    # input sharding, which would pollute the single-compile perf gate)
    return jax.vmap(functools.partial(_run_one, spec))(knobs)


def compile_cache_size() -> int:
    """Number of distinct programs compiled for run_grid so far (-1 if the
    introspection API is unavailable in this JAX version)."""
    try:
        return _run_grid_jit._cache_size()
    except Exception:
        return -1


def sharded_compile_cache_size() -> int:
    """Compile count of the device-sharded entry point (-1 = no introspection)."""
    try:
        return _run_grid_sharded_jit._cache_size()
    except Exception:
        return -1


def grid2d_compile_count() -> int:
    """Programs compiled by the 2-D ``config × node`` runners so far (-1 if
    the introspection API is unavailable)."""
    try:
        return sum(fn._cache_size() for fn in _GRID2D_RUNNERS.values())
    except Exception:
        return -1


def _warn_legacy(name: str) -> None:
    warnings.warn(
        f"repro.core.sweep.{name} is deprecated: use repro.api "
        "(ExperimentSpec -> plan -> execute; see DESIGN.md §8) — this shim "
        "delegates to it",
        DeprecationWarning,
        stacklevel=3,
    )


# ---------------------------------------------------------------------------
# Bucketing planner: static shape axes -> (padded spec, traced active knobs)
# ---------------------------------------------------------------------------


class BucketPlan(NamedTuple):
    """One shape bucket: configs that share a padded (coroutines,
    records_per_node, ticks) shape and therefore one XLA compilation.

    ``coroutines`` / ``records_per_node`` / ``ticks`` are the PADDED shapes
    baked into the bucket's GridSpec; the matching ``*_active`` field
    carries each config's true extent (None when every config already
    matches the padded shape — that axis then stays off the padding
    machinery).  Padded coroutine slots / record rows are physically inert;
    padded TICKS freeze the scan carry (early-exit masks), so in all three
    cases counters are bitwise-equal to the unpadded run.
    """

    indices: Tuple[int, ...]  # positions in the caller's config list
    coroutines: int
    records_per_node: int
    knob_configs: Tuple[Dict, ...]  # static axes stripped
    coroutines_active: Optional[Tuple[int, ...]]
    records_active: Optional[Tuple[int, ...]]
    ticks: Optional[int] = None  # None = every config uses the grid default
    ticks_active: Optional[Tuple[int, ...]] = None


def _pow2_ceil(v: int) -> int:
    return 1 << (int(v) - 1).bit_length()


def plan_buckets(
    configs: Sequence[Dict],
    *,
    coroutines: int,
    records_per_node: int,
    ticks: Optional[int] = None,
) -> List[BucketPlan]:
    """Group configs into shape buckets (one compile each).

    Each config may set the static axes in :data:`STATIC_AXES`; omitted
    axes take the grid-level default.  Bucket key = power-of-two ceiling of
    each axis (so nearby shapes share a program); bucket shape = max actual
    value inside the bucket (no padding beyond what the bucket needs).
    """
    groups: Dict[Tuple[int, int, int], List[Tuple[int, int, int, int, Dict]]] = {}
    for i, cfg in enumerate(configs):
        cfg = dict(cfg)
        c = int(cfg.pop("coroutines", coroutines))
        r = int(cfg.pop("records_per_node", records_per_node))
        has_t = "ticks" in cfg
        t = cfg.pop("ticks", ticks)
        t = 0 if t is None else int(t)  # 0 = axis unset (grid default applies)
        if c < 1 or r < 1:
            raise ValueError(f"config {i}: coroutines/records_per_node must be >= 1, got {c}/{r}")
        if has_t and t < 1:
            raise ValueError(f"config {i}: ticks must be >= 1, got {t}")
        groups.setdefault((_pow2_ceil(c), _pow2_ceil(r), _pow2_ceil(t) if t else 0), []).append(
            (i, c, r, t, cfg)
        )
    buckets = []
    for key in sorted(groups):
        rows = groups[key]
        pad_c = max(c for _, c, _, _, _ in rows)
        pad_r = max(r for _, _, r, _, _ in rows)
        pad_t = max(t for _, _, _, t, _ in rows)
        buckets.append(
            BucketPlan(
                indices=tuple(i for i, _, _, _, _ in rows),
                coroutines=pad_c,
                records_per_node=pad_r,
                knob_configs=tuple(cfg for _, _, _, _, cfg in rows),
                coroutines_active=(
                    None if all(c == pad_c for _, c, _, _, _ in rows)
                    else tuple(c for _, c, _, _, _ in rows)
                ),
                records_active=(
                    None if all(r == pad_r for _, _, r, _, _ in rows)
                    else tuple(r for _, _, r, _, _ in rows)
                ),
                ticks=pad_t or None,
                ticks_active=(
                    None if all(t == pad_t for _, _, _, t, _ in rows)
                    else tuple(t for _, _, _, t, _ in rows)
                ),
            )
        )
    return buckets


def _run_sharded(spec: GridSpec, knobs: RunKnobs, devices) -> Dict:
    """Dispatch one bucket's grid with the config axis sharded over devices.

    Pads the grid to a multiple of the device count by replicating the last
    config (the pad rows are sliced off the output — they never reach a
    caller), lays the knob pytree out with a 1-D ``grid`` mesh sharding,
    and lets jit partition the vmapped program over it.
    """
    n_dev = len(devices)
    size = int(np.asarray(knobs.seed).shape[0])
    pad = (-size) % n_dev
    if pad:
        knobs = jax.tree_util.tree_map(
            lambda x: jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)], axis=0), knobs
        )
    mesh = Mesh(np.asarray(devices), ("grid",))
    knobs = jax.device_put(knobs, NamedSharding(mesh, PartitionSpec("grid")))
    out = _run_grid_sharded_jit(spec, knobs)
    return {k: np.asarray(v)[:size] for k, v in out.items()}


# (GridSpec, device-key, node_shards) -> jitted 2-D grid runner
_GRID2D_RUNNERS: Dict[Tuple[GridSpec, Tuple[str, ...], int], Any] = {}


def _grid2d_runner(spec: GridSpec, devices: Sequence, node_shards: int):
    key = (spec, tuple(str(d) for d in devices), node_shards)
    fn = _GRID2D_RUNNERS.get(key)
    if fn is not None:
        return fn
    from jax.sharding import PartitionSpec as P

    from repro.core import planes

    n_cfg = len(devices) // node_shards
    mesh = Mesh(np.asarray(list(devices)).reshape(n_cfg, node_shards), ("grid", "node"))
    shard = planes.NodeShard(axis="node", n_shards=node_shards)

    @jax.jit
    def runner(knobs: RunKnobs) -> Dict:
        def body(kn_local):
            return jax.vmap(functools.partial(_run_one, spec, shard=shard))(kn_local)

        return planes.shard_map(
            body, mesh=mesh, in_specs=(P("grid"),), out_specs=P("grid"), check_rep=False
        )(knobs)

    _GRID2D_RUNNERS[key] = runner
    return runner


def _run_sharded_2d(spec: GridSpec, knobs: RunKnobs, devices, node_shards: int) -> Dict:
    """Dispatch one bucket's grid on a 2-D ``config × node`` mesh.

    The config axis splits over the mesh's ``grid`` axis exactly as
    :func:`_run_sharded`; each config's SIMULATION additionally runs
    node-sharded over the ``node`` axis (every plane exchange inside the
    vmapped engine batches over the local configs).  One ``shard_map``
    covers both axes, so the composition is a mesh-construction choice —
    the engine program is the same one :func:`~repro.core.engine.run_sharded`
    runs on a 1-D node mesh.
    """
    entry = registry.get_protocol(spec.protocol)
    if not entry.caps.batch_node_shardable:
        # e.g. calvin: the wave executor iterates a per-config traced wave
        # count — configs cannot batch around its node collectives
        raise ValueError(
            f"protocol {spec.protocol!r} cannot run on a 2-D config × node mesh: "
            "its registry entry sets Caps(batch_node_shardable=False); shard the "
            "config axis only (node_shards=None)"
        )
    if spec.n_nodes % node_shards:
        raise ValueError(
            f"node_shards={node_shards} must divide n_nodes={spec.n_nodes}"
        )
    n_cfg = len(devices) // node_shards
    size = int(np.asarray(knobs.seed).shape[0])
    pad = (-size) % n_cfg
    if pad:
        knobs = jax.tree_util.tree_map(
            lambda x: jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)], axis=0), knobs
        )
    out = _grid2d_runner(spec, devices, node_shards)(knobs)
    return {k: np.asarray(v)[:size] for k, v in out.items()}


def _legacy_grid(
    protocol: str,
    workload: str,
    configs: Iterable[Dict],
    *,
    devices: Optional[Sequence] = None,
    node_shards: Optional[int] = None,
    **kw,
) -> List[Dict]:
    """Map the historical run_grid signature onto ``repro.api.plan/execute``.

    Layout resolution reproduces the old in-module dispatch exactly:
    ``node_shards>1`` -> 2-D ``config x node`` mesh; ``len(devices)>1`` ->
    config-axis sharding; one explicit device -> dense with placement;
    otherwise dense.
    """
    from repro import api

    devices = list(devices) if devices is not None else None
    node_shards = node_shards if node_shards and node_shards > 1 else None
    if node_shards is not None:
        # historical contract: devices must be passed explicitly (the planner
        # would otherwise auto-resolve to all of jax.devices())
        n_dev = len(devices) if devices is not None else 1
        if n_dev % node_shards:
            raise ValueError(
                f"node_shards={node_shards} must divide the device count ({n_dev})"
            )
        layout = api.CONFIG_NODE
    elif devices is not None and len(devices) > 1:
        layout = api.CONFIG
    else:
        layout = api.DENSE
    spec = api.ExperimentSpec(
        protocol=protocol,
        workload=workload,
        configs=tuple(dict(c) for c in configs),
        devices=tuple(devices) if devices is not None else None,
        node_shards=node_shards,
        layout=layout,
        **kw,
    )
    return api.execute(api.plan(spec)).rows


def run_grid(
    protocol: str,
    workload: str,
    configs: Iterable[Dict],
    *,
    devices: Optional[Sequence] = None,
    node_shards: Optional[int] = None,
    **kw,
) -> List[Dict]:
    """DEPRECATED shim: use :mod:`repro.api` (``plan``/``execute``).

    Delegates to the planner with the historical layout rules, so counters
    are bitwise-identical to the old in-module dispatch (pinned by
    tests/test_api.py) and the row schema is unchanged.  Emits one
    :class:`DeprecationWarning`.
    """
    _warn_legacy("run_grid")
    return _legacy_grid(
        protocol, workload, configs, devices=devices, node_shards=node_shards, **kw
    )


def run_grid_sharded(
    protocol: str,
    workload: str,
    configs: Iterable[Dict],
    *,
    devices: Optional[Sequence] = None,
    **kw,
) -> List[Dict]:
    """DEPRECATED shim: use :mod:`repro.api` with ``devices="auto"``.

    ``devices`` defaults to all of :func:`jax.devices`; on a single device
    this degenerates to the dense program (same compiled entry point, zero
    overhead) — the planner keeps that contract.
    """
    _warn_legacy("run_grid_sharded")
    devices = list(devices) if devices is not None else list(jax.devices())
    return _legacy_grid(protocol, workload, configs, devices=devices, **kw)


# ---------------------------------------------------------------------------
# Node-sharded single-config runs (DESIGN.md §7): the SIMULATION axis on the
# device mesh — paper-scale single configs instead of many small configs.
# ---------------------------------------------------------------------------

# (GridSpec, device-key) -> jitted runner.  Knobs stay traced, so a whole
# family of configs (hybrids, seeds, exec_ticks, ...) shares ONE compiled
# SPMD program per mesh shape — the perf gate asserts this.
_NODE_RUNNERS: Dict[Tuple[GridSpec, Tuple[str, ...]], Any] = {}


def _node_runner(spec: GridSpec, devices: Sequence):
    key = (spec, tuple(str(d) for d in devices))
    fn = _NODE_RUNNERS.get(key)
    if fn is not None:
        return fn
    devs = list(devices)

    entry = registry.get_protocol(spec.protocol)

    @jax.jit
    def runner(kn: RunKnobs) -> Dict:
        cm = CostModel.tcp() if spec.tcp else CostModel(qp_pressure=kn.qp_pressure)
        wkw: Dict[str, Any] = {"exec_ticks": kn.exec_ticks}
        if spec.workload == "ycsb":
            wkw["hot_prob"] = kn.hot_prob
        wl = make_workload(spec.workload, spec.n_nodes * spec.records_per_node, **wkw)
        ec = EngineConfig(
            protocol=spec.protocol,
            n_nodes=spec.n_nodes,
            coroutines=spec.coroutines,
            records_per_node=spec.records_per_node,
            rw=wl.rw,
            max_ops=wl.max_ops,
            hybrid=kn.hybrid,
            doorbell=spec.doorbell,
            merge_stages=spec.merge_stages,
            exec_ticks=kn.exec_ticks,
            history_cap=spec.history_cap,
            mvcc_slots=spec.mvcc_slots,
            seed=kn.seed,
            kernel_plane=spec.kernel_plane,
        )
        return entry.hooks.node_run(
            entry, ec, cm, wl, ticks=spec.ticks, warmup=spec.warmup, devices=devs
        )

    _NODE_RUNNERS[key] = runner
    return runner


def node_sharded_compile_count() -> int:
    """Programs compiled by the node-sharded runners so far (-1 if the
    introspection API is unavailable): one per (GridSpec, mesh) pair when
    the knob tracing holds, regardless of how many configs ran."""
    try:
        return sum(fn._cache_size() for fn in _NODE_RUNNERS.values())
    except Exception:
        return -1


def run_cell_sharded(
    protocol: str,
    workload: str,
    config: Optional[Dict] = None,
    *,
    node_shards: Optional[int] = None,
    devices: Optional[Sequence] = None,
    **kw,
) -> Dict:
    """DEPRECATED shim: use :mod:`repro.api` with ``layout="node"``.

    One engine run with the simulated ``n_nodes`` axis SPMD on the mesh.
    ``devices`` picks the mesh explicitly; ``node_shards`` takes the first N
    of ``jax.devices()`` (their count must divide ``n_nodes``).  The jitted
    program is cached per (GridSpec, mesh) with every knob traced, so
    sweeping hybrids or seeds at a fixed mesh costs one compilation —
    ``api.ExecutionPlan.expected_compiles`` accounts for it.
    """
    _warn_legacy("run_cell_sharded")
    from repro import api

    spec = api.ExperimentSpec(
        protocol=protocol,
        workload=workload,
        configs=(dict(config or {}),),
        devices=tuple(devices) if devices is not None else None,
        node_shards=node_shards,
        layout=api.NODE,
        **kw,
    )
    return api.execute(api.plan(spec)).rows[0]
