"""Batched sweep engine: a grid of engine configurations as ONE program.

The paper's central experiment is an unbiased sweep over {protocol} x
{2^6 hybrid stage codings} x workload knobs.  Running each cell through a
fresh ``jax.jit`` costs one XLA compilation per cell — the exhaustive
hybrid enumeration alone is 64 compiles.  This module splits a run's
parameters into

  * a static :class:`GridSpec` (shapes + protocol + tick counts): one
    compilation per distinct spec, cached on the jitted entry point; and
  * traced :class:`RunKnobs` (hybrid coding as an int32[N_HYBRID_STAGES]
    array, seed, exec_ticks, hot_prob, qp_pressure): vmapped, so a whole
    grid of knob settings shares the single compiled ``lax.scan``.

``run_grid`` is the public API: it stacks the per-config knobs, runs
``vmap(run)`` under one jit, and unstacks the metrics into per-config
dicts shaped like ``benchmarks.common.run_cell``'s output.
"""
from __future__ import annotations

import functools
import itertools
import time
from typing import Any, Dict, Iterable, List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import N_HYBRID_STAGES, RPC, CostModel
from repro.core.engine import EngineConfig, run
from repro.core.protocols import PROTOCOLS
from repro.core.protocols import calvin as calvin_mod
from repro.workloads import make_workload

# Per-workload knob defaults, mirroring each factory's signature; resolved
# at grid-construction (Python) time so an unspecified knob reproduces the
# sequential run_cell exactly.
WL_EXEC_TICKS = {"smallbank": 1, "ycsb": 3, "tpcc": 5}
YCSB_HOT_PROB = 0.10

KNOB_KEYS = ("hybrid", "seed", "exec_ticks", "hot_prob", "qp_pressure")


class GridSpec(NamedTuple):
    """Static shape/compile params — one XLA compilation per distinct value."""

    protocol: str
    workload: str
    n_nodes: int = 4
    coroutines: int = 60
    records_per_node: int = 65536
    ticks: int = 400
    warmup: int = 80
    history_cap: int = 0
    mvcc_slots: int = 4
    doorbell: bool = True
    tcp: bool = False
    merge_stages: bool = False  # cross-stage doorbell merging (rounds.py §4.2)


class RunKnobs(NamedTuple):
    """Traced per-run knobs; in ``run_grid`` every leaf has a leading grid axis."""

    hybrid: Any  # int32[..., N_HYBRID_STAGES]
    seed: Any  # int32[...]
    exec_ticks: Any  # int32[...]
    hot_prob: Any  # float32[...]
    qp_pressure: Any  # float32[...]


def normalize_hybrid(code) -> Tuple[int, ...]:
    """Hybrid coding as a stage tuple; ints are bitmasks (bit i = stage i)."""
    if isinstance(code, (int, np.integer)):
        return tuple((int(code) >> i) & 1 for i in range(N_HYBRID_STAGES))
    code = tuple(int(b) for b in code)
    if len(code) != N_HYBRID_STAGES:
        raise ValueError(f"hybrid coding needs {N_HYBRID_STAGES} stages, got {code}")
    return code


def all_hybrid_codes() -> List[Tuple[int, ...]]:
    """All 2^N_HYBRID_STAGES stage codings (the paper's exhaustive sweep)."""
    return [normalize_hybrid(i) for i in range(2**N_HYBRID_STAGES)]


def grid_product(**axes: Sequence) -> List[Dict]:
    """Cartesian product of named knob axes -> list of config dicts."""
    names = list(axes)
    return [dict(zip(names, vals)) for vals in itertools.product(*(axes[n] for n in names))]


def make_knobs(workload: str, configs: Iterable[Dict]) -> RunKnobs:
    """Stack per-config knob dicts into a batched RunKnobs pytree.

    Each config may set any of ``hybrid`` (tuple or int bitmask), ``seed``,
    ``exec_ticks``, ``hot_prob``, ``qp_pressure``; omitted knobs take the
    workload's defaults.
    """
    configs = list(configs)
    if not configs:
        raise ValueError("empty config grid: pass at least one knob dict")
    rows = []
    for c in configs:
        c = dict(c)
        hy = normalize_hybrid(c.pop("hybrid", (RPC,) * N_HYBRID_STAGES))
        seed = int(c.pop("seed", 0))
        et = c.pop("exec_ticks", None)
        et = WL_EXEC_TICKS.get(workload, 1) if et is None else int(et)
        hp = c.pop("hot_prob", None)
        if hp is not None and workload != "ycsb":
            raise TypeError(f"hot_prob is a ycsb-only knob; workload={workload!r}")
        hp = YCSB_HOT_PROB if hp is None else float(hp)
        qp = float(c.pop("qp_pressure", 0.0))
        if c:
            raise TypeError(f"unknown knob(s): {sorted(c)}; valid: {KNOB_KEYS}")
        rows.append((hy, seed, et, hp, qp))
    hy, seed, et, hp, qp = zip(*rows)
    return RunKnobs(
        hybrid=jnp.asarray(np.array(hy, np.int32)),
        seed=jnp.asarray(np.array(seed, np.int32)),
        exec_ticks=jnp.asarray(np.array(et, np.int32)),
        hot_prob=jnp.asarray(np.array(hp, np.float32)),
        qp_pressure=jnp.asarray(np.array(qp, np.float32)),
    )


def _run_one(spec: GridSpec, kn: RunKnobs) -> Dict:
    """One engine run with traced knobs (vmapped over the grid axis)."""
    cm = CostModel.tcp() if spec.tcp else CostModel(qp_pressure=kn.qp_pressure)
    n_records = spec.n_nodes * spec.records_per_node
    wkw: Dict[str, Any] = {"exec_ticks": kn.exec_ticks}
    if spec.workload == "ycsb":
        wkw["hot_prob"] = kn.hot_prob
    wl = make_workload(spec.workload, n_records, **wkw)
    ec = EngineConfig(
        protocol=spec.protocol,
        n_nodes=spec.n_nodes,
        coroutines=spec.coroutines,
        records_per_node=spec.records_per_node,
        rw=wl.rw,
        max_ops=wl.max_ops,
        hybrid=kn.hybrid,
        doorbell=spec.doorbell,
        merge_stages=spec.merge_stages,
        exec_ticks=kn.exec_ticks,
        history_cap=spec.history_cap,
        mvcc_slots=spec.mvcc_slots,
        seed=kn.seed,
    )
    if spec.protocol == "calvin":
        n_epochs = max(spec.ticks // 8, 8)
        _, m = calvin_mod.run_epochs(ec, cm, wl, n_epochs)
    else:
        _, _, m = run(PROTOCOLS[spec.protocol].tick, ec, cm, wl, spec.ticks, warmup=spec.warmup)
    return m


@functools.partial(jax.jit, static_argnums=0)
def _run_grid_jit(spec: GridSpec, knobs: RunKnobs) -> Dict:
    return jax.vmap(functools.partial(_run_one, spec))(knobs)


def compile_cache_size() -> int:
    """Number of distinct programs compiled for run_grid so far (-1 if the
    introspection API is unavailable in this JAX version)."""
    try:
        return _run_grid_jit._cache_size()
    except Exception:
        return -1


def run_grid(
    protocol: str,
    workload: str,
    configs: Iterable[Dict],
    *,
    n_nodes: int = 4,
    coroutines: int = 60,
    records_per_node: int = 65536,
    ticks: int = 400,
    warmup: int = 80,
    history_cap: int = 0,
    mvcc_slots: int = 4,
    doorbell: bool = True,
    tcp: bool = False,
    merge_stages: bool = False,
) -> List[Dict]:
    """Run a whole grid of per-run knob settings as one vmapped program.

    ``configs`` is a list of knob dicts (see :func:`make_knobs`).  Returns
    one metrics dict per config, in order, with the same schema as
    ``benchmarks.common.run_cell`` (plus ``grid_size``); ``wall_s`` is the
    whole grid's wall clock, shared by every row.
    """
    configs = list(configs)
    spec = GridSpec(
        protocol=protocol,
        workload=workload,
        n_nodes=n_nodes,
        coroutines=coroutines,
        records_per_node=records_per_node,
        ticks=ticks,
        warmup=warmup,
        history_cap=history_cap,
        mvcc_slots=mvcc_slots,
        doorbell=doorbell,
        tcp=tcp,
        merge_stages=merge_stages,
    )
    knobs = make_knobs(workload, configs)
    t0 = time.time()
    out = _run_grid_jit(spec, knobs)
    out = {k: np.asarray(v) for k, v in out.items()}
    wall = round(time.time() - t0, 2)
    hy = np.asarray(knobs.hybrid)
    rows = []
    for g in range(len(configs)):
        m = {k: v[g].tolist() for k, v in out.items()}
        m["wall_s"] = wall
        m["grid_size"] = len(configs)
        m["protocol"], m["workload"] = protocol, workload
        m["hybrid"] = "".join(str(int(b)) for b in hy[g])
        rows.append(m)
    return rows
