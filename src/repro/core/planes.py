"""SPMD communication planes: the production mapping of RCC's two
primitive families onto mesh collectives (DESIGN.md §2).

The engine (engine.py) simulates the cluster on one device for benchmarks;
THIS module is the distribution-plane proof: the same tuple-store service
expressed with shard_map + jax.lax collectives over a `node` mesh axis, so
the dry-run can lower it onto the production mesh.

One-sided plane (`os_read` / `os_cas`): requests are address-only; the
owner shard performs raw gathers / arbitrated CAS (the RNIC's job — zero
protocol logic) and payloads return via the same all_to_all.  Two-sided
plane (`rpc_call`): the owner runs a vectorized *handler* on the delivered
requests (the remote CPU's job).  Both planes use one all_to_all exchange
per round = one network round, matching the engine's tick semantics.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

try:
    from jax import shard_map  # type: ignore
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore

from jax.sharding import Mesh, PartitionSpec as P

from repro.core.arbiter import scatter_min_winner


def _route(requests, dest, n_nodes, cap):
    """Pack per-node request buffers (n_nodes, cap, ...) by destination.

    requests (M, W) int32; dest (M,); entries beyond cap are dropped (the
    caller sizes cap = M for losslessness).
    """
    M = requests.shape[0]
    onehot = jax.nn.one_hot(dest, n_nodes, dtype=jnp.int32)  # (M, n)
    pos = jnp.cumsum(onehot, axis=0) - onehot  # rank within destination
    slot = (pos * onehot).sum(-1)
    keep = slot < cap
    buf = jnp.zeros((n_nodes, cap, requests.shape[1]), requests.dtype)
    buf = buf.at[dest, jnp.where(keep, slot, cap - 1)].set(
        jnp.where(keep[:, None], requests, 0), mode="drop"
    )
    valid = jnp.zeros((n_nodes, cap), bool).at[dest, jnp.where(keep, slot, cap - 1)].set(
        keep, mode="drop"
    )
    return buf, valid, slot


def make_planes(mesh: Mesh, axis: str, records_per_node: int, rw: int):
    """Returns jittable (os_read, os_cas, rpc_call) over a node-sharded store."""
    n_nodes = mesh.shape[axis]

    def os_read(store_data, keys):
        """One-sided READ: keys (n_local,) global keys per node shard.

        store_data sharded (node, R_local, rw); returns values for each key.
        The owner does NO protocol logic — just the DMA gather.
        """

        def body(data_l, keys_l):
            m = keys_l.shape[0]
            dest = keys_l // records_per_node
            req = jnp.stack([keys_l % records_per_node, jnp.arange(m, dtype=jnp.int32)], 1)
            buf, valid, slot = _route(req, dest, n_nodes, m)
            inbox = jax.lax.all_to_all(buf, axis, 0, 0, tiled=True)  # (n*m? ...)
            inbox = inbox.reshape(n_nodes, m, 2)
            # RNIC DMA: raw gather, no handler logic
            vals = data_l[jnp.clip(inbox[..., 0], 0, data_l.shape[0] - 1)]
            back = jax.lax.all_to_all(vals.reshape(n_nodes * m, rw), axis, 0, 0, tiled=True)
            back = back.reshape(n_nodes, m, rw)
            # un-route: value for local request i sits at (dest[i], slot-in-dest)
            out = back[dest, slot]
            return out

        return shard_map(
            body, mesh=mesh, in_specs=(P(axis, None), P(axis)), out_specs=P(axis, None)
        )(store_data, keys)

    def os_cas(lock_words, keys, new_vals):
        """One-sided CAS (expect-free): arbitrated at the owner's memory
        controller; returns won-mask.  lock_words sharded (node, R_local)."""

        def body(lock_l, keys_l, new_l):
            m = keys_l.shape[0]
            dest = keys_l // records_per_node
            req = jnp.stack(
                [keys_l % records_per_node, new_l, jnp.arange(m, dtype=jnp.int32)], 1
            )
            buf, valid, slot = _route(req, dest, n_nodes, m)
            inbox = jax.lax.all_to_all(buf, axis, 0, 0, tiled=True).reshape(n_nodes, m, 3)
            vwin = jax.lax.all_to_all(valid.astype(jnp.int32), axis, 0, 0, tiled=True)
            v = vwin.reshape(n_nodes * m) > 0
            addr = inbox.reshape(-1, 3)[:, 0]
            newv = inbox.reshape(-1, 3)[:, 1]
            win = scatter_min_winner(
                addr, jnp.zeros_like(addr), jnp.arange(addr.shape[0], dtype=jnp.int32), v, lock_l.shape[0]
            )
            free = lock_l[jnp.clip(addr, 0, lock_l.shape[0] - 1)] == 0
            ok = win & free & v
            lock_l = lock_l.at[jnp.where(ok, addr, lock_l.shape[0])].set(
                jnp.where(ok, newv, 0), mode="drop"
            )
            okb = jax.lax.all_to_all(
                ok.reshape(n_nodes, m).astype(jnp.int32), axis, 0, 0, tiled=True
            ).reshape(n_nodes, m)
            return lock_l, okb[dest, slot] > 0

        return shard_map(
            body,
            mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis)),
            out_specs=(P(axis), P(axis)),
        )(lock_words, keys, new_vals)

    def rpc_call(store_data, keys, handler: Callable):
        """Two-sided RPC: requests routed to owners; the OWNER's CPU runs
        `handler(data_local, addrs) -> (data_local', replies)`."""

        def body(data_l, keys_l):
            m = keys_l.shape[0]
            dest = keys_l // records_per_node
            req = jnp.stack([keys_l % records_per_node, jnp.arange(m, dtype=jnp.int32)], 1)
            buf, valid, slot = _route(req, dest, n_nodes, m)
            inbox = jax.lax.all_to_all(buf, axis, 0, 0, tiled=True).reshape(n_nodes, m, 2)
            vmask = jax.lax.all_to_all(valid.astype(jnp.int32), axis, 0, 0, tiled=True)
            data_l, replies = handler(data_l, inbox[..., 0].reshape(-1), vmask.reshape(-1) > 0)
            back = jax.lax.all_to_all(
                replies.reshape(n_nodes * m, -1), axis, 0, 0, tiled=True
            ).reshape(n_nodes, m, -1)
            return data_l, back[dest, slot]

        return shard_map(
            body,
            mesh=mesh,
            in_specs=(P(axis, None), P(axis)),
            out_specs=(P(axis, None), P(axis, None)),
        )(store_data, keys)

    return os_read, os_cas, rpc_call
