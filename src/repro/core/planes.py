"""SPMD communication planes: the production mapping of RCC's two
primitive families onto mesh collectives (DESIGN.md §2, §7).

The engine (engine.py) simulates the cluster on one device for benchmarks;
THIS module is the distribution plane: the same tuple-store service
expressed with shard_map + jax.lax collectives over a `node` mesh axis.
Two layers live here:

  * the **request-routed planes** (`make_planes`): requests packed into
    per-destination buffers and exchanged with `all_to_all` — the
    standalone proof that one engine round maps onto one fabric exchange.
  * the **engine transport** (`NodeShard` + the `node_*` primitives):
    what `engine.run_sharded` actually runs on.  The store lives sharded
    (each mesh shard owns its nodes' record rows — data, locks, versions);
    the tiny per-slot coordinator state is sequencer-replicated, so every
    request set is known mesh-wide and a round needs exactly ONE reply
    exchange: the owner shard does the gather / arbitrated CAS / capacity
    ranking on its local rows (the RNIC's / handler CPU's job) and replies
    combine with a `psum` whose every addend is zero except the owner's —
    bytes on the wire = bytes in the collective.  `node_read_batch` is the
    doorbell-batched multi-op round (§4.2): several metadata words for the
    same key set ride one exchange.

One-sided plane (`os_read` / `os_cas`): requests are address-only; the
owner shard performs raw gathers / arbitrated CAS (the RNIC's job — zero
protocol logic) and payloads return via the same all_to_all.  Two-sided
plane (`rpc_call`): the owner runs a vectorized *handler* on the delivered
requests (the remote CPU's job).  Both planes use one all_to_all exchange
per round = one network round, matching the engine's tick semantics.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

try:
    from jax import shard_map  # type: ignore
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore

from jax.sharding import Mesh, PartitionSpec as P

from repro.core.arbiter import scatter_min_winner
from repro.kernels import ops as kops


# ---------------------------------------------------------------------------
# Engine transport: node-sharded store primitives (DESIGN.md §7)
# ---------------------------------------------------------------------------


class NodeShard(NamedTuple):
    """Mesh placement of the simulated cluster (EngineConfig.shard).

    ``axis`` is the mesh axis name the store's record rows are sharded
    over; ``n_shards`` its size.  Simulated nodes map onto shards in
    contiguous blocks (n_nodes % n_shards == 0), so a shard owns whole
    nodes' record ranges and the dense engine's key -> owner arithmetic
    is preserved.  A None shard on EngineConfig means the dense
    single-device engine — every primitive below then degenerates to the
    plain gather/scatter it replaces, keeping one code path.
    """

    axis: str
    n_shards: int


def _local_ix(shard: NodeShard, r_local: int, keys):
    """Global row ids -> (local row ids clipped in range, ownership mask).

    The read-side form: gather from the clipped index, mask the value.
    """
    off = jax.lax.axis_index(shard.axis).astype(jnp.int32) * r_local
    li = keys.astype(jnp.int32) - off
    mine = (li >= 0) & (li < r_local)
    return jnp.clip(li, 0, r_local - 1), mine


def local_ix_drop(shard: NodeShard, r_local: int, idx):
    """Global row ids -> local row ids with non-owned rows at the drop
    sentinel ``r_local`` (the write-side form: scatter with mode="drop").
    The caller's own drop sentinel (>= global rows) lands out of every
    shard's range and stays dropped."""
    off = jax.lax.axis_index(shard.axis).astype(jnp.int32) * r_local
    li = idx.astype(jnp.int32) - off
    return jnp.where((li < 0) | (li >= r_local), r_local, li)


def node_read(shard: NodeShard, arr, keys):
    """One-sided READ round: gather global rows of a node-sharded array.

    ``arr`` is the LOCAL shard (r_local, ...); ``keys`` (...,) global row
    ids (replicated).  The owner does the DMA gather on its rows; replies
    combine in one psum exchange (all other shards contribute zeros).
    """
    kf = keys.reshape(-1)
    li, mine = _local_ix(shard, arr.shape[0], kf)
    vals = arr[li]
    vals = jnp.where(mine.reshape((-1,) + (1,) * (arr.ndim - 1)), vals, 0)
    out = jax.lax.psum(vals, shard.axis)
    return out.reshape(keys.shape + arr.shape[1:])


def node_read_batch(shard: NodeShard, arrs: Sequence, keys, *, kernel_plane: str = "jnp") -> Tuple:
    """Doorbell-batched multi-op READ: several arrays, same keys, ONE
    exchange.  The per-array replies are flattened along a feature axis,
    psum'd together, and split back — the collective analogue of posting
    dependent reads in a single doorbell (§4.2).  On a Pallas kernel plane
    the owner's local gather is the fused multi-read kernel over the packed
    table (the RNIC's DMA engine); the exchange structure is identical."""
    kf = keys.reshape(-1)
    li, mine = _local_ix(shard, arrs[0].shape[0], kf)
    if kops.is_pallas(kernel_plane):
        table, widths = kops.pack_rows(arrs)
        v = kops.gather_rows_batch(table, li, plane=kernel_plane)
        out = jax.lax.psum(jnp.where(mine[:, None], v, 0), shard.axis)
    else:
        flat = []
        for a in arrs:
            v = a[li].reshape(kf.shape[0], -1)
            flat.append(jnp.where(mine[:, None], v, 0))
        widths = [f.shape[1] for f in flat]
        out = jax.lax.psum(jnp.concatenate(flat, axis=1), shard.axis)
    return kops.unpack_rows(out, arrs, widths, keys.shape)


def node_read2(shard: NodeShard, arr, keys, sel):
    """READ of (row, slot) pairs from a (r_local, S, ...) sharded array
    (MVCC version-slot fetch).  One exchange."""
    kf, sf = keys.reshape(-1), sel.reshape(-1)
    li, mine = _local_ix(shard, arr.shape[0], kf)
    vals = arr[li, sf]
    vals = jnp.where(mine.reshape((-1,) + (1,) * (arr.ndim - 2)), vals, 0)
    out = jax.lax.psum(vals, shard.axis)
    return out.reshape(keys.shape + arr.shape[2:])


def node_write(shard: NodeShard, arr, idx, vals, *, op: str = "set"):
    """One-sided WRITE round: scatter into global rows of a sharded array.

    ``idx`` (M,) global row ids with the caller's drop sentinel >= the
    global row count for masked-off requests (the dense convention).  The
    request set is sequencer-replicated, so the owner applies its rows'
    updates locally and NO reply exchange is needed (write acks carry no
    payload).  ``op`` in {"set", "add"}.
    """
    li = local_ix_drop(shard, arr.shape[0], idx)
    if op == "add":
        return arr.at[li].add(vals, mode="drop")
    return arr.at[li].set(vals, mode="drop")


def node_write2(shard: NodeShard, arr, idx, sel, vals, *, op: str = "set"):
    """WRITE of (row, slot) pairs into a (r_local, S, ...) sharded array."""
    li = local_ix_drop(shard, arr.shape[0], idx)
    if op == "add":
        return arr.at[li, sel].add(vals, mode="drop")
    return arr.at[li, sel].set(vals, mode="drop")


def node_cas_winner(shard: NodeShard, r_local: int, keys, prio_hi, prio_lo, active,
                    *, kernel_plane: str = "jnp"):
    """One-sided CAS arbitration round: per-key (prio_hi, prio_lo) minimum.

    The owner shard arbitrates the requests that target its rows — its
    memory controller serializes the CASes, exactly `scatter_min_winner`
    over the local range (or the all-pairs arbitration kernel on a Pallas
    plane: same lexicographic-min winners bitwise) — and the won-bits
    combine in one psum exchange.  Bitwise-equal to the dense global
    arbitration: every key's contest happens entirely at its owner with
    the same priorities.
    """
    li, mine = _local_ix(shard, r_local, keys)
    win_l = kops.cas_arbitrate(li, prio_hi, prio_lo, active & mine, r_local, plane=kernel_plane)
    return jax.lax.psum(win_l.astype(jnp.int32), shard.axis) > 0


def _route(requests, dest, n_nodes, cap):
    """Pack per-node request buffers (n_nodes, cap, ...) by destination.

    requests (M, W) int32; dest (M,); entries beyond cap are dropped (the
    caller sizes cap = M for losslessness).
    """
    onehot = jax.nn.one_hot(dest, n_nodes, dtype=jnp.int32)  # (M, n)
    pos = jnp.cumsum(onehot, axis=0) - onehot  # rank within destination
    slot = (pos * onehot).sum(-1)
    keep = slot < cap
    # dropped requests scatter to an out-of-bounds destination (discarded by
    # mode="drop") instead of aliasing into slot cap-1 and clobbering the
    # request legitimately routed there
    dest_k = jnp.where(keep, dest, n_nodes)
    slot_k = jnp.where(keep, slot, 0)
    buf = jnp.zeros((n_nodes, cap, requests.shape[1]), requests.dtype)
    buf = buf.at[dest_k, slot_k].set(requests, mode="drop")
    valid = jnp.zeros((n_nodes, cap), bool).at[dest_k, slot_k].set(True, mode="drop")
    return buf, valid, slot


def make_planes(mesh: Mesh, axis: str, records_per_node: int, rw: int, cap: int = 0):
    """Returns jittable (os_read, os_cas, rpc_call) over a node-sharded store.

    ``cap`` bounds the per-destination request buffer (0 = size it for
    losslessness, i.e. the per-shard request count).  With a finite cap,
    requests beyond it are DROPPED by the routing fabric: their replies
    come back zero / not-won, never another request's payload (the reply
    un-route masks by the routing validity, mirroring an RNIC dropping
    work requests when the send queue overflows).
    """
    n_nodes = mesh.shape[axis]

    def os_read(store_data, keys):
        """One-sided READ: keys (n_local,) global keys per node shard.

        store_data sharded (node, R_local, rw); returns values for each key
        (zeros for requests dropped by a finite ``cap``).  The owner does
        NO protocol logic — just the DMA gather.
        """

        def body(data_l, keys_l):
            m = keys_l.shape[0]
            c = cap or m
            dest = keys_l // records_per_node
            req = jnp.stack([keys_l % records_per_node, jnp.arange(m, dtype=jnp.int32)], 1)
            buf, _, slot = _route(req, dest, n_nodes, c)
            inbox = jax.lax.all_to_all(buf, axis, 0, 0, tiled=True)  # (n*c, 2)
            inbox = inbox.reshape(n_nodes, c, 2)
            # RNIC DMA: raw gather, no handler logic
            vals = data_l[jnp.clip(inbox[..., 0], 0, data_l.shape[0] - 1)]
            back = jax.lax.all_to_all(vals.reshape(n_nodes * c, rw), axis, 0, 0, tiled=True)
            back = back.reshape(n_nodes, c, rw)
            # un-route: value for local request i sits at (dest[i], slot-in-dest);
            # dropped requests (slot >= c) must NOT alias slot c-1
            keep = slot < c
            out = back[dest, jnp.minimum(slot, c - 1)]
            return jnp.where(keep[:, None], out, 0)

        return shard_map(
            body, mesh=mesh, in_specs=(P(axis, None), P(axis)), out_specs=P(axis, None)
        )(store_data, keys)

    def os_cas(lock_words, keys, new_vals):
        """One-sided CAS (expect-free): arbitrated at the owner's memory
        controller; returns won-mask.  lock_words sharded (node, R_local)."""

        def body(lock_l, keys_l, new_l):
            m = keys_l.shape[0]
            c = cap or m
            dest = keys_l // records_per_node
            req = jnp.stack(
                [keys_l % records_per_node, new_l, jnp.arange(m, dtype=jnp.int32)], 1
            )
            buf, valid, slot = _route(req, dest, n_nodes, c)
            inbox = jax.lax.all_to_all(buf, axis, 0, 0, tiled=True).reshape(n_nodes, c, 3)
            vwin = jax.lax.all_to_all(valid.astype(jnp.int32), axis, 0, 0, tiled=True)
            v = vwin.reshape(n_nodes * c) > 0
            addr = inbox.reshape(-1, 3)[:, 0]
            newv = inbox.reshape(-1, 3)[:, 1]
            win = scatter_min_winner(
                addr, jnp.zeros_like(addr), jnp.arange(addr.shape[0], dtype=jnp.int32), v, lock_l.shape[0]
            )
            free = lock_l[jnp.clip(addr, 0, lock_l.shape[0] - 1)] == 0
            ok = win & free & v
            lock_l = lock_l.at[jnp.where(ok, addr, lock_l.shape[0])].set(
                jnp.where(ok, newv, 0), mode="drop"
            )
            okb = jax.lax.all_to_all(
                ok.reshape(n_nodes, c).astype(jnp.int32), axis, 0, 0, tiled=True
            ).reshape(n_nodes, c)
            # dropped requests never won (and must not alias slot c-1's result)
            keep = slot < c
            return lock_l, (okb[dest, jnp.minimum(slot, c - 1)] > 0) & keep

        return shard_map(
            body,
            mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis)),
            out_specs=(P(axis), P(axis)),
        )(lock_words, keys, new_vals)

    def rpc_call(store_data, keys, handler: Callable):
        """Two-sided RPC: requests routed to owners; the OWNER's CPU runs
        `handler(data_local, addrs) -> (data_local', replies)`."""

        def body(data_l, keys_l):
            m = keys_l.shape[0]
            c = cap or m
            dest = keys_l // records_per_node
            req = jnp.stack([keys_l % records_per_node, jnp.arange(m, dtype=jnp.int32)], 1)
            buf, valid, slot = _route(req, dest, n_nodes, c)
            inbox = jax.lax.all_to_all(buf, axis, 0, 0, tiled=True).reshape(n_nodes, c, 2)
            vmask = jax.lax.all_to_all(valid.astype(jnp.int32), axis, 0, 0, tiled=True)
            data_l, replies = handler(data_l, inbox[..., 0].reshape(-1), vmask.reshape(-1) > 0)
            back = jax.lax.all_to_all(
                replies.reshape(n_nodes * c, -1), axis, 0, 0, tiled=True
            ).reshape(n_nodes, c, -1)
            # dropped requests get a zero reply, not another request's payload
            keep = slot < c
            return data_l, jnp.where(keep[:, None], back[dest, jnp.minimum(slot, c - 1)], 0)

        return shard_map(
            body,
            mesh=mesh,
            in_specs=(P(axis, None), P(axis)),
            out_specs=(P(axis, None), P(axis, None)),
        )(store_data, keys)

    return os_read, os_cas, rpc_call
