"""SPMD communication planes: the production mapping of RCC's two
primitive families onto mesh collectives (DESIGN.md §2).

The engine (engine.py) simulates the cluster on one device for benchmarks;
THIS module is the distribution-plane proof: the same tuple-store service
expressed with shard_map + jax.lax collectives over a `node` mesh axis, so
the dry-run can lower it onto the production mesh.

One-sided plane (`os_read` / `os_cas`): requests are address-only; the
owner shard performs raw gathers / arbitrated CAS (the RNIC's job — zero
protocol logic) and payloads return via the same all_to_all.  Two-sided
plane (`rpc_call`): the owner runs a vectorized *handler* on the delivered
requests (the remote CPU's job).  Both planes use one all_to_all exchange
per round = one network round, matching the engine's tick semantics.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

try:
    from jax import shard_map  # type: ignore
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore

from jax.sharding import Mesh, PartitionSpec as P

from repro.core.arbiter import scatter_min_winner


def _route(requests, dest, n_nodes, cap):
    """Pack per-node request buffers (n_nodes, cap, ...) by destination.

    requests (M, W) int32; dest (M,); entries beyond cap are dropped (the
    caller sizes cap = M for losslessness).
    """
    onehot = jax.nn.one_hot(dest, n_nodes, dtype=jnp.int32)  # (M, n)
    pos = jnp.cumsum(onehot, axis=0) - onehot  # rank within destination
    slot = (pos * onehot).sum(-1)
    keep = slot < cap
    # dropped requests scatter to an out-of-bounds destination (discarded by
    # mode="drop") instead of aliasing into slot cap-1 and clobbering the
    # request legitimately routed there
    dest_k = jnp.where(keep, dest, n_nodes)
    slot_k = jnp.where(keep, slot, 0)
    buf = jnp.zeros((n_nodes, cap, requests.shape[1]), requests.dtype)
    buf = buf.at[dest_k, slot_k].set(requests, mode="drop")
    valid = jnp.zeros((n_nodes, cap), bool).at[dest_k, slot_k].set(True, mode="drop")
    return buf, valid, slot


def make_planes(mesh: Mesh, axis: str, records_per_node: int, rw: int, cap: int = 0):
    """Returns jittable (os_read, os_cas, rpc_call) over a node-sharded store.

    ``cap`` bounds the per-destination request buffer (0 = size it for
    losslessness, i.e. the per-shard request count).  With a finite cap,
    requests beyond it are DROPPED by the routing fabric: their replies
    come back zero / not-won, never another request's payload (the reply
    un-route masks by the routing validity, mirroring an RNIC dropping
    work requests when the send queue overflows).
    """
    n_nodes = mesh.shape[axis]

    def os_read(store_data, keys):
        """One-sided READ: keys (n_local,) global keys per node shard.

        store_data sharded (node, R_local, rw); returns values for each key
        (zeros for requests dropped by a finite ``cap``).  The owner does
        NO protocol logic — just the DMA gather.
        """

        def body(data_l, keys_l):
            m = keys_l.shape[0]
            c = cap or m
            dest = keys_l // records_per_node
            req = jnp.stack([keys_l % records_per_node, jnp.arange(m, dtype=jnp.int32)], 1)
            buf, _, slot = _route(req, dest, n_nodes, c)
            inbox = jax.lax.all_to_all(buf, axis, 0, 0, tiled=True)  # (n*c, 2)
            inbox = inbox.reshape(n_nodes, c, 2)
            # RNIC DMA: raw gather, no handler logic
            vals = data_l[jnp.clip(inbox[..., 0], 0, data_l.shape[0] - 1)]
            back = jax.lax.all_to_all(vals.reshape(n_nodes * c, rw), axis, 0, 0, tiled=True)
            back = back.reshape(n_nodes, c, rw)
            # un-route: value for local request i sits at (dest[i], slot-in-dest);
            # dropped requests (slot >= c) must NOT alias slot c-1
            keep = slot < c
            out = back[dest, jnp.minimum(slot, c - 1)]
            return jnp.where(keep[:, None], out, 0)

        return shard_map(
            body, mesh=mesh, in_specs=(P(axis, None), P(axis)), out_specs=P(axis, None)
        )(store_data, keys)

    def os_cas(lock_words, keys, new_vals):
        """One-sided CAS (expect-free): arbitrated at the owner's memory
        controller; returns won-mask.  lock_words sharded (node, R_local)."""

        def body(lock_l, keys_l, new_l):
            m = keys_l.shape[0]
            c = cap or m
            dest = keys_l // records_per_node
            req = jnp.stack(
                [keys_l % records_per_node, new_l, jnp.arange(m, dtype=jnp.int32)], 1
            )
            buf, valid, slot = _route(req, dest, n_nodes, c)
            inbox = jax.lax.all_to_all(buf, axis, 0, 0, tiled=True).reshape(n_nodes, c, 3)
            vwin = jax.lax.all_to_all(valid.astype(jnp.int32), axis, 0, 0, tiled=True)
            v = vwin.reshape(n_nodes * c) > 0
            addr = inbox.reshape(-1, 3)[:, 0]
            newv = inbox.reshape(-1, 3)[:, 1]
            win = scatter_min_winner(
                addr, jnp.zeros_like(addr), jnp.arange(addr.shape[0], dtype=jnp.int32), v, lock_l.shape[0]
            )
            free = lock_l[jnp.clip(addr, 0, lock_l.shape[0] - 1)] == 0
            ok = win & free & v
            lock_l = lock_l.at[jnp.where(ok, addr, lock_l.shape[0])].set(
                jnp.where(ok, newv, 0), mode="drop"
            )
            okb = jax.lax.all_to_all(
                ok.reshape(n_nodes, c).astype(jnp.int32), axis, 0, 0, tiled=True
            ).reshape(n_nodes, c)
            # dropped requests never won (and must not alias slot c-1's result)
            keep = slot < c
            return lock_l, (okb[dest, jnp.minimum(slot, c - 1)] > 0) & keep

        return shard_map(
            body,
            mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis)),
            out_specs=(P(axis), P(axis)),
        )(lock_words, keys, new_vals)

    def rpc_call(store_data, keys, handler: Callable):
        """Two-sided RPC: requests routed to owners; the OWNER's CPU runs
        `handler(data_local, addrs) -> (data_local', replies)`."""

        def body(data_l, keys_l):
            m = keys_l.shape[0]
            c = cap or m
            dest = keys_l // records_per_node
            req = jnp.stack([keys_l % records_per_node, jnp.arange(m, dtype=jnp.int32)], 1)
            buf, valid, slot = _route(req, dest, n_nodes, c)
            inbox = jax.lax.all_to_all(buf, axis, 0, 0, tiled=True).reshape(n_nodes, c, 2)
            vmask = jax.lax.all_to_all(valid.astype(jnp.int32), axis, 0, 0, tiled=True)
            data_l, replies = handler(data_l, inbox[..., 0].reshape(-1), vmask.reshape(-1) > 0)
            back = jax.lax.all_to_all(
                replies.reshape(n_nodes * c, -1), axis, 0, 0, tiled=True
            ).reshape(n_nodes, c, -1)
            # dropped requests get a zero reply, not another request's payload
            keep = slot < c
            return data_l, jnp.where(keep[:, None], back[dest, jnp.minimum(slot, c - 1)], 0)

        return shard_map(
            body,
            mesh=mesh,
            in_specs=(P(axis, None), P(axis)),
            out_specs=(P(axis, None), P(axis, None)),
        )(store_data, keys)

    return os_read, os_cas, rpc_call
