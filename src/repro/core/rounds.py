"""Declarative stage-graph runtime (DESIGN.md §3).

RCC's promise is a *common execution environment* in which the concurrency
control protocol is the only changeable component.  This module makes that
environment code instead of convention: a protocol is a table of
:class:`StageSpec` rows (canonical cost-stage id, op-mask fn, wire-cost
entry, effect hook, success/fail transitions) and :func:`make_tick` compiles
the table into the engine's per-tick function.  The full round lifecycle —

    want-mask -> service_ops -> effect hook -> account_round
              -> served bookkeeping -> stage transition

— lives in :func:`run_stage_round`, once, so the five engine protocols
differ only in their tables and small jnp effect hooks.

Cross-stage doorbell merging (paper §4.2, DESIGN.md §4) is a runtime pass
over the same tables: when a stage declares ``fuse_next`` and the merge
predicate holds (both stages coded one-sided, doorbell batching on,
``EngineConfig.merge_stages`` set), completed transactions skip the
intermediate stage and its wire bytes ride the absorbing stage's doorbell —
one MMIO, one RTT, one fewer engine tick.  The predicate is jnp-composable,
so a batched sweep (repro.core.sweep) fuses per-config inside one compiled
program.

Everything here must stay knob-traceable: no Python branching on hybrid
codings, seeds, or exec_ticks (see EngineConfig's static/traced split).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core.costmodel import (
    ONE_SIDED,
    RPC,
    ST_COMMIT,
    ST_LOG,
    ST_VALIDATE,
    CostModel,
    wire_cost,
)

FRESH = -1  # st["stage"] sentinel: slot regenerates a new txn next tick

# StageSpec.kind values
ROUND = "round"  # serviced network round (lock/fetch/validate/commit/release)
LOG = "log"  # fire-and-forget replicated log round (no service arbitration)
EXEC = "exec"  # local execution phase (no network)


class StageOut(NamedTuple):
    """What an effect hook hands back to the driver.

    ``fail``: (N,) txns aborting out of this stage (routed by
    :func:`abort_to_retry`).  ``served_acc``: override for what accumulates
    into ``st["served"]`` (default: everything served this round; a lock
    stage under one-sided coding accumulates nothing — it re-posts every
    tick).  ``outstanding``: override for the completion check (default:
    the stage's op mask re-evaluated after bookkeeping; lock stages
    complete on ``~locked``, not ``~served``).
    """

    st: Dict
    store: Dict
    fail: Optional[jnp.ndarray] = None
    served_acc: Optional[jnp.ndarray] = None
    outstanding: Optional[jnp.ndarray] = None


@dataclass(frozen=True)
class StageSpec:
    """One row of a protocol's stage table.

    ``stage`` is the protocol-local id stored in ``st["stage"]``; ``canon``
    is the canonical cost stage (ST_*) that picks the hybrid primitive, the
    latency bucket, and the :class:`~repro.core.costmodel.WireCost` row.
    ``ops(ec, wl, st) -> (N,K)`` is the want basis (the driver ANDs the
    in-stage mask); ``effect`` applies the stage's store/state mutation for
    the ops actually served.  ``done`` picks the completion rule:

      * ``"advance"``: all ops complete -> ``next_stage``; with
        ``ro_commit`` set, transactions with an empty write set instead
        commit here (the declarative read-only fast path — no lock/log/
        commit rounds); failures go through the shared abort path.
      * ``"commit"``: all ops complete -> finish_commit + slot regen.
      * ``"abort"``: all locks released -> finish_abort + retry at
        ``next_stage``.
    """

    stage: int
    canon: int
    kind: str = ROUND
    ops: Optional[Callable] = None
    effect: Optional[Callable] = None
    next_stage: int = FRESH
    done: str = "advance"
    retry_stage: Optional[int] = None  # fail: restart stage (no locks held)
    abrel_stage: Optional[int] = None  # fail: abort-release stage (locks held)
    new_ts: bool = False  # retry with a fresh (larger) timestamp
    start_exec: bool = False  # completion enters the execution phase
    salt_off: int = 0  # service_ops salt offset (pins arbitration RNG draws)
    # declarative read-only fast path: protocols whose read-set validation
    # doubles as the commit point (mvcc's rts round) set this instead of
    # forking the driver with a routing override — RO-fast-path protocols
    # are table entries, not code forks
    ro_commit: bool = False
    fuse_next: Optional[int] = None  # next_stage when doorbell merging fires
    fuse_absorbs: Optional[int] = None  # canon id whose bytes ride this doorbell


# ---------------------------------------------------------------------------
# Cross-stage doorbell merging (§4.2): the fusable-pair merge table
# ---------------------------------------------------------------------------

# Protocol -> ordered (absorber, absorbed) canonical stage pairs.  A pair
# fires when both stages are coded one-sided, doorbell batching is on, and
# the config opts in via ``merge_stages``; the FIRST firing pair for an
# absorbed stage claims it (an earlier absorber shadows later ones), so at
# most one doorbell carries the absorbed bytes.  Write-heavy OCC registers
# VALIDATE→LOG (the validation CAS round and the log WRITEs post as one
# doorbell batch) ahead of the family-default LOG→COMMIT fusion.
MERGE_TABLE: Dict[str, Tuple[Tuple[int, int], ...]] = {
    "default": ((ST_COMMIT, ST_LOG),),
    "occ": ((ST_VALIDATE, ST_LOG), (ST_COMMIT, ST_LOG)),
}


def merge_pairs(protocol: str) -> Tuple[Tuple[int, int], ...]:
    from repro.core.registry import protocol_family

    return MERGE_TABLE.get(protocol_family(protocol), MERGE_TABLE["default"])


def _pair_on(ec: eng.EngineConfig, absorber: int, absorbed: int):
    """Raw pair predicate (ignoring precedence).  jnp-composable: under a
    batched sweep the hybrid coding is traced and fusion resolves per grid
    row at runtime; off by default (``merge_stages``) so pre-merge counters
    stay bitwise reproducible."""
    if not (ec.merge_stages and ec.doorbell):
        return jnp.asarray(False)
    hy = ec.hybrid
    return (jnp.asarray(hy[absorber]) == ONE_SIDED) & (jnp.asarray(hy[absorbed]) == ONE_SIDED)


def fuse_log_commit(ec: eng.EngineConfig):
    """The family-default pair: LOG rides the COMMIT doorbell (legacy name)."""
    return _pair_on(ec, ST_COMMIT, ST_LOG)


def log_rides(ec: eng.EngineConfig, st: Dict):
    """Which doorbell carries each txn's LOG bytes: ``(absorbed, by_v, by_c)``.

    Resolved PER TRANSACTION: the VALIDATE→LOG pair can only carry a txn
    that actually posts a validate round (non-empty read set) — a
    write-only txn's log WRITEs fall through to the next registered pair
    (COMMIT), or to a plain LOG round when nothing absorbs them.  All masks
    broadcast against (N,) (scalars when only scalar pairs are registered,
    so non-occ protocols keep the original single-predicate program).
    """
    by_v = jnp.asarray(False)
    by_c = jnp.asarray(False)
    for a, b in merge_pairs(ec.protocol):
        if b != ST_LOG:
            continue
        if a == ST_VALIDATE:
            has_rs = (st["valid"] & ~st["is_w"]).any(1)
            by_v = by_v | (_pair_on(ec, a, b) & has_rs)
        elif a == ST_COMMIT:
            by_c = by_c | _pair_on(ec, a, b)
    by_c = by_c & ~by_v  # first registered pair claims the stage
    return by_v | by_c, by_v, by_c


def _resolve_next(ec: eng.EngineConfig, spec: StageSpec, st: Dict):
    # fuse_next routes past the LOG stage for txns whose log bytes have a
    # doorbell to ride (per-txn under the occ VALIDATE→LOG pair)
    if spec.fuse_next is None:
        return spec.next_stage
    absorbed, _, _ = log_rides(ec, st)
    return jnp.where(absorbed, spec.fuse_next, spec.next_stage)


def _stage_wire(ec: eng.EngineConfig, cm: CostModel, wl, spec: StageSpec, st: Dict):
    """(bytes, n_verbs) for one round, with absorbed-stage bytes when fused.

    Absorbed LOG bytes apply per op and only where a write set exists: on a
    COMMIT doorbell they ride the WRITE ops (a read-only txn's commit round
    releases locks but ships no log message); on a VALIDATE doorbell they
    ride the read-set ops of txns that also carry writes.  Bytes may then
    be (N,K), which broadcasts through account_round's wire term.
    """
    wc = wire_cost(ec.protocol, spec.canon)
    nb = wc.bytes_for(wl.rw, cm.n_backups)
    if spec.fuse_absorbs is not None and ec.merge_stages and ec.doorbell:
        extra = wire_cost(ec.protocol, spec.fuse_absorbs).bytes_for(wl.rw, cm.n_backups)
        _, by_v, by_c = log_rides(ec, st)
        if spec.canon == ST_VALIDATE:
            has_ws = (st["valid"] & st["is_w"]).any(1)
            on = jnp.asarray(by_v & has_ws)[:, None] & st["valid"] & ~st["is_w"]
        else:
            on = jnp.asarray(by_c)
            on = (on[:, None] if on.ndim else on) & st["is_w"]
        nb = nb + jnp.where(on, extra, 0.0)
    return nb, wc.n_verbs


# ---------------------------------------------------------------------------
# Shared effect building blocks
# ---------------------------------------------------------------------------


def apply_commit(ec: eng.EngineConfig, store: Dict, st: Dict, eff, *, bump_seq: bool = False) -> Dict:
    """Write back wvals + release this txn's locks for served commit ops.

    The single write-back used by the 2PL family and OCC (``bump_seq``
    additionally advances OCC's validation sequence word).
    """
    keys_f = st["keys"].reshape(-1)
    w_eff = (eff & st["is_w"]).reshape(-1)
    idx_w = jnp.where(w_eff, keys_f, ec.n_records)
    store = dict(store)
    store["data"] = eng.write_rows(
        ec, store["data"], idx_w, st["wvals"].reshape(-1, st["wvals"].shape[-1])
    )
    store["ver"] = eng.write_rows(ec, store["ver"], idx_w, 1, op="add")
    if bump_seq:
        store["seq"] = eng.write_rows(ec, store["seq"], idx_w, 1, op="add")
    rel = (eff & st["locked"]).reshape(-1)
    idx_r = jnp.where(rel, keys_f, ec.n_records)
    store["lock_hi"] = eng.write_rows(ec, store["lock_hi"], idx_r, 0)
    store["lock_lo"] = eng.write_rows(ec, store["lock_lo"], idx_r, 0)
    return store


def writeback_commit_effect(*, bump_seq: bool = False) -> Callable:
    """COMMIT effect hook for protocols using the plain write-back."""

    def effect(ec, cm, wl, st, store, in_s, served, salt):
        store = apply_commit(ec, store, st, served, bump_seq=bump_seq)
        st = dict(st)
        st["locked"] = st["locked"] & ~served
        return StageOut(st, store)

    return effect


def release_effect(ec, cm, wl, st, store, in_s, served, salt) -> StageOut:
    """ABORT-RELEASE effect: zero the lock words this txn still holds."""
    store = eng.release_locks(ec, store, st, served)
    st = dict(st)
    st["locked"] = st["locked"] & ~served
    return StageOut(st, store)


def ops_valid(ec, wl, st):
    """All valid ops not yet served (fetch/commit-style stages)."""
    return st["valid"] & ~st["served"]


def ops_write_set(ec, wl, st):
    """Write-set ops not yet served (occ/sundial/mvcc commit)."""
    return st["valid"] & st["is_w"] & ~st["served"]


def ops_read_set(ec, wl, st):
    """Read-set ops not yet served (validate stages)."""
    return st["valid"] & ~st["is_w"] & ~st["served"]


def ops_locked(ec, wl, st):
    """Held locks not yet released (abort-release stages)."""
    return st["locked"] & ~st["served"]


def ops_lock_pending(write_only: bool) -> Callable:
    """Lock-stage want basis: unlocked (write-set) ops.  One-sided lock
    requests re-post every tick, so ``served`` does NOT mask the basis."""

    def ops(ec, wl, st):
        base = st["valid"] & st["is_w"] if write_only else st["valid"]
        # ~served only bites under RPC park-the-waiter semantics (twopl);
        # one-sided lock stages never accumulate served, so it is vacuous
        return base & ~st["locked"] & ~st["served"]

    return ops


def abort_to_retry(st: Dict, fail, spec: StageSpec) -> Dict:
    """Route failing txns: ABREL when holding locks, else immediate retry.

    Immediate retries count the abort and zero the latency/round counters;
    ``spec.new_ts`` additionally takes a fresh (larger) timestamp (mvcc /
    sundial retry rule — 2PL keeps the original so WAITDIE requesters age).
    """
    has_locks = st["locked"].any(1)
    st = dict(st)
    st["stage"] = jnp.where(
        fail, jnp.where(has_locks, spec.abrel_stage, spec.retry_stage), st["stage"]
    )
    insta = fail & ~has_locks
    st = eng.finish_abort(st, insta)
    st = dict(st)
    if spec.new_ts:
        st["clock"] = jnp.where(insta, st["clock"] + 1, st["clock"])
        st["ts_hi"] = jnp.where(insta, st["clock"], st["ts_hi"])
    st["lat_us"] = jnp.where(insta, 0.0, st["lat_us"])
    st["rounds"] = jnp.where(insta, 0, st["rounds"])
    return st


# ---------------------------------------------------------------------------
# The driver
# ---------------------------------------------------------------------------


def run_stage_round(
    ec: eng.EngineConfig, cm: CostModel, wl, st: Dict, store: Dict, spec: StageSpec, salt
) -> Tuple[Dict, Dict]:
    """One serviced network round for ``spec``: the full lifecycle."""
    prim = ec.hybrid[spec.canon]
    in_s = st["stage"] == spec.stage
    want = in_s[:, None] & spec.ops(ec, wl, st)
    served, load = eng.service_ops(ec, cm, st, want, prim == RPC, salt)
    out = spec.effect(ec, cm, wl, st, store, in_s, served, salt)
    st, store = dict(out.st), out.store
    nbytes, n_verbs = _stage_wire(ec, cm, wl, spec, st)
    st = eng.account_round(ec, cm, st, spec.canon, served, load, prim, nbytes, n_verbs=n_verbs)
    st = dict(st)
    acc = served if out.served_acc is None else out.served_acc
    st["served"] = st["served"] | acc

    if spec.done == "abort":
        done = in_s & ~st["locked"].any(1)
        st = eng.finish_abort(st, done)
        st = dict(st)
        if spec.new_ts:
            st["clock"] = jnp.where(done, st["clock"] + 1, st["clock"])
            st["ts_hi"] = jnp.where(done, st["clock"], st["ts_hi"])
        st["stage"] = jnp.where(done, spec.next_stage, st["stage"])
        st["served"] = jnp.where(done[:, None], False, st["served"])
        st["lat_us"] = jnp.where(done, 0.0, st["lat_us"])
        st["rounds"] = jnp.where(done, 0, st["rounds"])
        return st, store

    outstanding = out.outstanding
    if outstanding is None:
        outstanding = in_s[:, None] & spec.ops(ec, wl, st)
    done = in_s & ~outstanding.any(1)

    if spec.done == "commit":
        st = eng.finish_commit(ec, cm, st, done)
        st = dict(st)
        st["stage"] = jnp.where(done, FRESH, st["stage"])
        st["served"] = jnp.where(done[:, None], False, st["served"])
        return st, store

    # "advance"
    fail = out.fail
    exit_mask = done
    if fail is not None:
        done = done & ~fail
        exit_mask = done | fail
        st = abort_to_retry(st, fail, spec)
    if spec.ro_commit:
        # declarative read-only fast path: txns with an empty write set
        # commit on completing this stage (no lock/log/commit rounds)
        has_ws = (st["valid"] & st["is_w"]).any(1)
        ro_done = done & ~has_ws
        st = eng.finish_commit(ec, cm, st, ro_done)
        st = dict(st)
        st["stage"] = jnp.where(ro_done, FRESH, st["stage"])
        done = done & has_ws
    st["stage"] = jnp.where(done, _resolve_next(ec, spec, st), st["stage"])
    if spec.start_exec:
        st["exec_left"] = jnp.where(done, wl.exec_ticks, st["exec_left"])
    st["served"] = jnp.where(exit_mask[:, None], False, st["served"])
    st["substep"] = jnp.where(exit_mask, 0, st["substep"])
    return st, store


def _log_round(ec: eng.EngineConfig, cm: CostModel, wl, st: Dict, spec: StageSpec) -> Dict:
    """Coordinator log to the replication group: one fire-and-forget round.

    No service arbitration (backups only append); read-only txns advance
    for free.  Txns whose LOG bytes found a doorbell to ride
    (:func:`log_rides`) are routed PAST this stage per transaction; the
    ones with no ride — e.g. occ write-only txns when only the
    VALIDATE→LOG pair fires — still land here and pay the real round, so
    this stage is live even with merging on.
    """
    prim = ec.hybrid[spec.canon]
    in_g = st["stage"] == spec.stage
    ops = in_g[:, None] & st["is_w"] & st["valid"]
    load = jnp.full(ops.shape, float(cm.n_backups), jnp.float32)
    nbytes, n_verbs = _stage_wire(ec, cm, wl, spec, st)
    st = eng.account_round(ec, cm, st, spec.canon, ops, load, prim, nbytes, n_verbs=n_verbs)
    st = dict(st)
    st["stage"] = jnp.where(in_g, spec.next_stage, st["stage"])
    st["served"] = jnp.where(in_g[:, None], False, st["served"])
    return st


def _exec_stage(ec: eng.EngineConfig, wl, st: Dict, spec: StageSpec) -> Dict:
    """Local execution phase: burn exec_left ticks, then run the workload's
    execute fn and advance (possibly straight past a fused LOG stage)."""
    in_e = st["stage"] == spec.stage
    st = dict(st)
    st["exec_left"] = jnp.where(in_e, jnp.maximum(st["exec_left"] - 1, 0), st["exec_left"])
    done_e = in_e & (st["exec_left"] == 0)
    wv = jax.vmap(wl.execute)(st["keys"], st["is_w"], st["valid"], st["rvals"])
    st["wvals"] = jnp.where(done_e[:, None, None], wv, st["wvals"])
    st["stage"] = jnp.where(done_e, _resolve_next(ec, spec, st), st["stage"])
    return st


def canon_table(specs: Tuple[StageSpec, ...]) -> Tuple[int, ...]:
    """Protocol-stage -> canonical-stage map derived from a stage table."""
    by_stage = {s.stage: s.canon for s in specs}
    return tuple(by_stage[i] for i in range(len(by_stage)))


def canon_of(stage, canon_map: Tuple[int, ...]):
    """Map st["stage"] values to canonical cost stages (-1 = inactive)."""
    canon = jnp.full_like(stage, -1)
    for ps, c in enumerate(canon_map):
        canon = jnp.where(stage == ps, c, canon)
    return canon


def begin_tick(
    ec: eng.EngineConfig,
    cm: CostModel,
    wl,
    st: Dict,
    canon_map: Tuple[int, ...],
    start_stage: int,
    fresh_hook: Optional[Callable] = None,
) -> Dict:
    """Regenerate fresh slots and charge every active txn its tick base.

    Bucket-padded (dead) slots stay at stage -1 forever: they are excluded
    from ``fresh``, so they never generate transactions, never enter any
    stage mask, and never touch a counter (DESIGN.md §6).
    """
    fresh = st["stage"] < 0
    alive = eng.alive_mask(ec)
    if alive is not None:
        fresh = fresh & alive
    st = eng.regen_txns(ec, wl, st, fresh, new_ts=True)
    st = dict(st)
    st["stage"] = jnp.where(fresh, start_stage, st["stage"])
    if fresh_hook is not None:
        st = fresh_hook(st, fresh)
    return eng.base_time(ec, cm, st, canon_of(st["stage"], canon_map))


def make_tick(
    *,
    specs: Tuple[StageSpec, ...],
    start_stage: int,
    salt_mult: int,
    fresh_hook: Optional[Callable] = None,
) -> Callable:
    """Compile a stage table into the engine's per-tick function.

    ``specs`` are processed in the given order — reverse pipeline order, so
    a transaction advances at most one network stage per tick (the engine's
    bulk-synchronous contract).  ``salt_mult`` namespaces each protocol's
    arbitration RNG stream.
    """
    canon_map = canon_table(specs)

    def tick(ec: eng.EngineConfig, cm: CostModel, wl, st: Dict, store: Dict, t):
        salt = t * salt_mult
        st = begin_tick(ec, cm, wl, st, canon_map, start_stage, fresh_hook)
        for spec in specs:
            if spec.kind == ROUND:
                st, store = run_stage_round(ec, cm, wl, st, store, spec, salt + spec.salt_off)
            elif spec.kind == LOG:
                st = _log_round(ec, cm, wl, st, spec)
            else:
                st = _exec_stage(ec, wl, st, spec)
        return st, store

    return tick
