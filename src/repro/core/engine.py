"""Bulk-synchronous vectorized transaction engine.

Execution model (paper §3.2 mapped to lockstep SPMD, see DESIGN.md §2):
one engine *tick* = one network round.  Every node runs C co-routine slots;
each slot drives one transaction through its protocol's stage machine.  A
stage occupies >= 1 tick depending on the primitive (one-sided CAS->READ is
2 rounds unless doorbell-batched; RPC is 1 round + remote-CPU queueing).

Capacity semantics (what creates the paper's effects):
  * RPC requests queue on the destination handler CPU: a node services at
    most `handler_cap - exec_load` RPC requests per tick (local co-routines
    busy in their execution phase starve the handler — Fig. 9), excess
    requests are deferred a tick.
  * one-sided verbs queue on the RNIC (`nic_cap`, degraded by QP pressure
    for emulated large clusters — Fig. 10).

All state lives in dense arrays; a tick is one jitted function; runs are
`lax.scan`s — the whole simulator is differentiable-by-accident and fast.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cmod
from repro.core import planes
from repro.core.arbiter import hash_prio
from repro.kernels import ops as kops
from repro.core.costmodel import N_STAGES, RPC, CostModel
from repro.core.planes import NodeShard
from repro.core.store import init_store
from repro.core.timestamps import TS, ts_eq, ts_is_zero


@dataclass(frozen=True)
class EngineConfig:
    """Engine configuration, split into two kinds of fields.

    *Static shape params* (protocol, n_nodes, coroutines, records_per_node,
    rw, max_ops, doorbell, history_cap, mvcc_slots) determine array shapes
    and compiled program structure; they must be concrete Python values and
    every distinct combination costs one XLA compilation.

    *Per-run knobs* (hybrid, exec_ticks, seed) may hold traced jnp scalars /
    arrays: no protocol code is allowed to Python-branch on them, so a whole
    grid of knob settings can share one compiled program via
    `repro.core.sweep.run_grid` (vmap over configs).  `hybrid` is either a
    Python tuple (sequential path — XLA folds the selects) or an
    int32[N_HYBRID_STAGES] array (batched path — `lax.select` at runtime).

    *Bucketed padding* (DESIGN.md §6): `active_coroutines` /
    `active_records_per_node` turn the two static shape axes into traced
    knobs.  The arrays are sized for the padded shapes (`coroutines`,
    `records_per_node`) while only the first `active_*` coroutine slots per
    node run transactions and only the first `active_records_per_node`
    record offsets per node are addressable; padded slots stay at stage -1
    forever and padded records are never generated, so neither leaks into
    commit/abort/latency/byte counters.  Every identity-derived value
    (RNG streams, timestamps, arbitration priorities) uses LOGICAL ids —
    `logical_ids` / `op_index` below — so a padded run is bitwise-equal to
    the same config run unpadded.  `None` (the default) means "axis not
    padded": the logical ids fold to the physical ones at trace time.

    *Kernel plane* (DESIGN.md §9): `kernel_plane` selects the backend for
    the three fused hot paths — lock arbitration, the MVCC version pick,
    and the doorbell-batched multi-read ("jnp" reference gather/scatter,
    "pallas" compiled kernels, "pallas_interpret" for CPU CI).  Static, so
    it is part of the compiled program identity; every plane keeps integer
    counters bitwise-equal to "jnp" (the kernel-parity CI contract).

    *Node sharding* (DESIGN.md §7): `shard` is None for the dense
    single-device engine, or a :class:`~repro.core.planes.NodeShard` when
    the tick runs SPMD under `shard_map` (see :func:`run_sharded`).  Store
    arrays are then LOCAL shards (each mesh shard owns whole simulated
    nodes' record rows) and every store access in the engine and the
    protocol effect hooks routes through the plane primitives below
    (`read_rows` / `write_rows` / `arb_winner` / ...), which lower to the
    dense gather/scatter when `shard` is None and to owner-local work plus
    one collective exchange per round when sharded.
    """

    protocol: str
    n_nodes: int = 4
    coroutines: int = 10  # per node (paper default: 10 threads x co-routines)
    records_per_node: int = 16384
    # traced active extents for bucket-padded sweeps (None = unpadded axis)
    active_coroutines: Any = None
    active_records_per_node: Any = None
    rw: int = 2  # record words (YCSB 64B = 16)
    max_ops: int = 4  # K
    hybrid: Tuple[int, ...] = (RPC,) * N_STAGES  # primitive per stage (traceable)
    doorbell: bool = True
    # cross-stage doorbell merging (paper §4.2, rounds.fuse_log_commit):
    # static opt-in — off by default so counters stay bitwise reproducible
    # against the pre-merge stage machines
    merge_stages: bool = False
    exec_ticks: int = 1  # execution-phase ticks (YCSB computation knob, traceable)
    history_cap: int = 0  # >0: record commit history for serializability checks
    mvcc_slots: int = 4  # MVCC static version slots (paper: 4; ablation knob)
    seed: int = 0  # traceable
    # kernel plane for the fused hot paths (static; see kernels/ops.py)
    kernel_plane: str = "jnp"
    # node-sharded SPMD execution (None = dense single-device engine)
    shard: Optional[NodeShard] = None

    @property
    def n_slots(self) -> int:
        return self.n_nodes * self.coroutines

    @property
    def n_records(self) -> int:
        return self.n_nodes * self.records_per_node

    @property
    def records_local(self) -> int:
        """Store rows owned by one mesh shard (= n_records when dense)."""
        return self.n_records // (self.shard.n_shards if self.shard else 1)


class Workload(NamedTuple):
    name: str
    rw: int
    max_ops: int
    init_value: int
    # gen(key, slot_node, slot_id) -> (keys (K,), is_w (K,), valid (K,))
    gen: Callable
    # execute(keys, is_w, valid, rvals (K,RW)) -> wvals (K,RW)
    execute: Callable
    exec_ticks: int = 1


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------


def init_state(ec: EngineConfig, wl: Workload) -> Dict:
    N, K, RW = ec.n_slots, ec.max_ops, wl.rw
    def z(*s):
        return jnp.zeros(s, jnp.int32)

    def zb(*s):
        return jnp.zeros(s, bool)

    def zf(*s):
        return jnp.zeros(s, jnp.float32)

    st = {
        "keys": z(N, K),
        "is_w": zb(N, K),
        "valid": zb(N, K),
        "rvals": z(N, K, RW),
        "wvals": z(N, K, RW),
        "stage": jnp.full((N,), -1, jnp.int32),  # -1 => fresh slot
        "substep": z(N),
        "ts_hi": z(N),
        "ts_lo": z(N),
        "clock": z(N),
        "locked": zb(N, K),
        "served": zb(N, K),
        "seq_seen": z(N, K),
        "ver_seen": z(N, K),
        "wts_seen_hi": z(N, K),  # sundial: wts at fetch time
        "wts_seen_lo": z(N, K),
        "commit_hi": z(N),  # sundial: commit_tts lease
        "commit_lo": z(N),
        "exec_left": z(N),
        "lat_us": zf(N),
        "rounds": z(N),
        "txn_no": z(N),
        "n_commit": z(N),
        "n_abort": z(N),
        "lat_sum": zf(N),
        "rt_sum": zf(N),
        "stage_us": zf(N_STAGES),
        "wait_us": zf(1),
        "tick": z(1),
    }
    if ec.history_cap:
        H = ec.history_cap
        st["h_idx"] = z(1)
        st["h_keys"] = z(H, K)
        st["h_ver_r"] = z(H, K)
        st["h_ver_w"] = z(H, K)
        st["h_isw"] = zb(H, K)
        st["h_valid"] = zb(H, K)
        st["h_ts_hi"] = z(H)
        st["h_ts_lo"] = z(H)
    return st


def slot_ids(ec: EngineConfig):
    sid = jnp.arange(ec.n_slots, dtype=jnp.int32)
    return sid, sid // ec.coroutines  # (slot, node)


def logical_ids(ec: EngineConfig):
    """(logical slot id, node, alive mask) under bucket padding.

    The logical id is the slot's identity in the UNPADDED system
    (node * active_coroutines + coroutine); every id-derived quantity (RNG
    folds, timestamp lo words, arbitration priorities) must use it so a
    padded run stays bitwise-equal to its unpadded reference.  ``alive`` is
    None when the coroutine axis is unpadded (the physical ids already are
    the logical ids and no slot is dead).
    """
    sid, node = slot_ids(ec)
    if ec.active_coroutines is None:
        return sid, node, None
    c = sid % ec.coroutines
    act = jnp.asarray(ec.active_coroutines, jnp.int32)
    return node * act + c, node, c < act


def alive_mask(ec: EngineConfig):
    """(n_slots,) bool of live slots, or None when nothing is padded."""
    return logical_ids(ec)[2]


def op_index(ec: EngineConfig, k: int):
    """(n_slots, k) logical flat op index: ``lsid * k + op``.

    Identity basis for hashed arbitration priorities (twopl/occ lock
    stages); equals ``arange(n_slots * k)`` when the coroutine axis is
    unpadded and stays padding-invariant otherwise.
    """
    lsid, _, _ = logical_ids(ec)
    return lsid[:, None] * k + jnp.arange(k, dtype=jnp.int32)[None, :]


def physical_keys(ec: EngineConfig, keys):
    """Map workload-generated LOGICAL keys onto the padded store layout.

    Logical key k (over n_nodes * active_records_per_node records) keeps
    its owning node and per-node offset: node k // aR gets physical row
    ``node * records_per_node + k % aR``.  Identity when the record axis is
    unpadded.  Monotone, so per-key orderings (arbitration, version chains,
    CALVIN waves) are preserved bitwise.
    """
    if ec.active_records_per_node is None:
        return keys
    a_r = jnp.asarray(ec.active_records_per_node, jnp.int32)
    return (keys // a_r) * ec.records_per_node + keys % a_r


def regen_txns(ec: EngineConfig, wl: Workload, st: Dict, mask, *, new_ts=True) -> Dict:
    """Generate fresh transactions for slots in `mask`.

    All identity flows through LOGICAL slot ids so bucket-padded runs match
    their unpadded references bitwise; dead (padded) slots never regenerate.
    """
    lsid, node, alive = logical_ids(ec)
    if alive is not None:
        mask = mask & alive
    key0 = jax.random.PRNGKey(ec.seed)

    def gen_one(s, n, t_no):
        k = jax.random.fold_in(jax.random.fold_in(key0, s), t_no)
        return wl.gen(k, n, s)

    keys, is_w, valid = jax.vmap(gen_one)(lsid, node, st["txn_no"])
    keys = physical_keys(ec, keys)
    st = dict(st)
    m2 = mask[:, None]
    st["keys"] = jnp.where(m2, keys, st["keys"])
    st["is_w"] = jnp.where(m2, is_w, st["is_w"])
    st["valid"] = jnp.where(m2, valid, st["valid"])
    st["txn_no"] = jnp.where(mask, st["txn_no"] + 1, st["txn_no"])
    st["locked"] = jnp.where(m2, False, st["locked"])
    st["served"] = jnp.where(m2, False, st["served"])
    st["substep"] = jnp.where(mask, 0, st["substep"])
    st["rounds"] = jnp.where(mask, 0, st["rounds"])
    st["lat_us"] = jnp.where(mask, 0.0, st["lat_us"])
    if new_ts:
        clock = st["clock"] + mask.astype(jnp.int32)
        # lo encodes the unique LOGICAL slot id (padding-invariant)
        ts = TS(jnp.asarray(clock, jnp.int32), jnp.asarray(lsid + 1, jnp.int32))
        st["ts_hi"] = jnp.where(mask, ts.hi, st["ts_hi"])
        st["ts_lo"] = jnp.where(mask, ts.lo, st["ts_lo"])
        st["clock"] = clock
    return st


def txn_ts(st) -> TS:
    return TS(st["ts_hi"], st["ts_lo"])


# ---------------------------------------------------------------------------
# Per-tick service-capacity model
# ---------------------------------------------------------------------------


def service_ops(ec: EngineConfig, cm: CostModel, st: Dict, op_mask, primitive_is_rpc, salt):
    """Which requested ops get served this tick, given per-node capacities.

    op_mask (N,K) bool: ops wanting a round this tick.  Returns
    (served (N,K), dest_load (N,K) fp32 — same-plane load at each op's dest).

    Node-sharded: the per-(dest, plane) ranking is the DESTINATION's job —
    each shard ranks only the requests arriving at its nodes (its handler
    CPU / RNIC queue) and the served bits combine in one reply exchange.
    Owned groups rank identically to the dense global sort (segment ranks
    are per-group), so the outcome is bitwise-equal.
    """
    N, K = op_mask.shape
    keys_f = st["keys"].reshape(-1)
    active = op_mask.reshape(-1)
    dest = jnp.clip(keys_f // ec.records_per_node, 0, ec.n_nodes - 1)
    is_rpc_f = jnp.broadcast_to(primitive_is_rpc, op_mask.shape).reshape(-1)

    # execution-phase co-routines starve their node's RPC handler (Fig. 9)
    _, node, _ = logical_ids(ec)
    exec_load = jnp.zeros((ec.n_nodes,), jnp.int32).at[node].add(
        (st["exec_left"] > 0).astype(jnp.int32)
    )
    rpc_cap = jnp.maximum(cm.handler_cap - exec_load * jnp.maximum(1, ec.exec_ticks), 1)
    nic_eff = jnp.asarray(cm.nic_eff_cap(), jnp.float32).astype(jnp.int32)
    nic_cap = jnp.broadcast_to(nic_eff, (ec.n_nodes,))

    # destination-side view: when sharded, a shard only ranks the requests
    # targeting the nodes it owns (the rest sort to the inactive tail)
    if ec.shard is None:
        arrived = active
    else:
        nodes_per_shard = ec.n_nodes // ec.shard.n_shards
        my_node = (dest // nodes_per_shard) == jax.lax.axis_index(ec.shard.axis)
        arrived = active & my_node

    # rank requests within (dest, plane) by hashed priority (arrival order);
    # the LOGICAL op index keeps the draws padding-invariant
    prio = hash_prio(op_index(ec, K).reshape(-1) + st["ts_lo"].repeat(K), salt)
    group = dest * 2 + is_rpc_f.astype(jnp.int32)
    sort_key = jnp.where(arrived, group * (2**20) + (prio & (2**20 - 1)), 2**30)
    order = jnp.argsort(sort_key)
    # rank within group via cumulative count in sorted order
    g_sorted = group[order]
    first = jnp.concatenate([jnp.ones(1, bool), g_sorted[1:] != g_sorted[:-1]])
    idx_in_sorted = jnp.arange(N * K)
    seg_start = jnp.where(first, idx_in_sorted, 0)
    seg_start = jax.lax.associative_scan(jnp.maximum, seg_start)
    rank_sorted = idx_in_sorted - seg_start
    rank = jnp.zeros(N * K, jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))

    cap = jnp.where(is_rpc_f, rpc_cap[dest], nic_cap[dest])
    served = arrived & (rank < cap)
    if ec.shard is not None:
        # served-bit reply exchange back to the coordinators
        served = jax.lax.psum(served.astype(jnp.int32), ec.shard.axis) > 0

    # same-plane per-dest load (for queue-delay accounting; (n_nodes, 2) is
    # coordinator bookkeeping over the replicated request set — no exchange)
    load = jnp.zeros((ec.n_nodes, 2), jnp.int32).at[dest, is_rpc_f.astype(jnp.int32)].add(
        active.astype(jnp.int32)
    )
    op_load = load[dest, is_rpc_f.astype(jnp.int32)].astype(jnp.float32)
    return served.reshape(N, K), op_load.reshape(N, K)


def base_time(ec: EngineConfig, cm: CostModel, st: Dict, canon_stage) -> Dict:
    """Per-tick base time: every active txn spends tick_us in its stage.

    canon_stage (N,) int32: canonical cost-stage id of each active txn
    (negative => inactive).  Round extras (queue delay, wire, MMIO, plane
    RTT delta) are added separately by account_round.
    """
    st = dict(st)
    active = canon_stage >= 0
    st["lat_us"] = st["lat_us"] + jnp.where(active, cm.tick_us, 0.0)
    st["stage_us"] = st["stage_us"].at[jnp.where(active, canon_stage, N_STAGES)].add(
        jnp.where(active, cm.tick_us, 0.0), mode="drop"
    )
    return st


def account_round(
    ec: EngineConfig,
    cm: CostModel,
    st: Dict,
    stage_id: int,
    op_mask,
    op_load,
    primitive: int,
    bytes_per_op: float,
    n_verbs: int = 1,
) -> Dict:
    """Attribute one round's *extras* (beyond the tick base) per txn.

    extras = (plane RTT - tick) + MMIO + wire bytes + destination queueing.
    Also counts the network round for the round-trip metric (Fig. 5).
    """
    is_rpc = jnp.asarray(primitive == RPC)
    per_op = cmod.round_latency_us(
        cm, is_rpc, op_load, bytes_per_op, n_verbs=n_verbs, doorbell=ec.doorbell
    ) - cm.tick_us
    per_op = jnp.where(op_mask, per_op, -jnp.inf)
    per_txn = per_op.max(axis=1)  # outstanding requests overlap within a round
    txn_mask = op_mask.any(axis=1)
    per_txn = jnp.where(txn_mask, per_txn, 0.0)
    st = dict(st)
    st["lat_us"] = st["lat_us"] + per_txn
    st["rounds"] = st["rounds"] + txn_mask.astype(jnp.int32)
    st["stage_us"] = st["stage_us"].at[stage_id].add(per_txn.sum())
    return st


# ---------------------------------------------------------------------------
# Store access helpers (the two communication planes differ only in cost and
# round structure; raw memory semantics are identical — DESIGN.md §2).
# Every helper routes through the planes.py transport when the config is
# node-sharded (DESIGN.md §7): the store array is then a LOCAL shard and the
# remote access becomes owner-local work plus one collective exchange.
# ---------------------------------------------------------------------------


def gather_rows(arr, keys):
    """arr (R, ...) at keys (N,K) -> (N,K,...) (dense, whole-store view)."""
    return arr[keys.reshape(-1)].reshape(keys.shape + arr.shape[1:])


def read_rows(ec: EngineConfig, arr, keys):
    """Plane-routed row gather: one-sided READ round when node-sharded."""
    if ec.shard is None:
        return gather_rows(arr, keys)
    return planes.node_read(ec.shard, arr, keys)


def read_rows_many(ec: EngineConfig, arrs: Sequence, keys) -> Tuple:
    """Gather several store arrays at the same keys.

    Dense: independent gathers (jnp plane) or ONE packed multi-read kernel
    dispatch (Pallas planes).  Sharded: ONE doorbell-batched exchange
    (planes.node_read_batch) — dependent metadata reads of a round ride a
    single collective, mirroring §4.2's doorbell batching.
    """
    if ec.shard is None:
        if kops.is_pallas(ec.kernel_plane):
            return kops.gather_many(arrs, keys, plane=ec.kernel_plane)
        return tuple(gather_rows(a, keys) for a in arrs)
    return planes.node_read_batch(ec.shard, arrs, keys, kernel_plane=ec.kernel_plane)


def read_rows2(ec: EngineConfig, arr, keys, sel):
    """(row, slot) gather from a (R, S, ...) store array (MVCC versions)."""
    if ec.shard is None:
        flat = arr[keys.reshape(-1), sel.reshape(-1)]
        return flat.reshape(keys.shape + arr.shape[2:])
    return planes.node_read2(ec.shard, arr, keys, sel)


def write_rows(ec: EngineConfig, arr, idx, vals, *, op: str = "set"):
    """Plane-routed row scatter.  ``idx`` (M,) global rows with the dense
    drop sentinel (>= n_records) for masked-off requests."""
    if ec.shard is None:
        if op == "add":
            return arr.at[idx].add(vals, mode="drop")
        return arr.at[idx].set(vals, mode="drop")
    return planes.node_write(ec.shard, arr, idx, vals, op=op)


def write_rows2(ec: EngineConfig, arr, idx, sel, vals, *, op: str = "set"):
    """(row, slot) scatter into a (R, S, ...) store array."""
    if ec.shard is None:
        if op == "add":
            return arr.at[idx, sel].add(vals, mode="drop")
        return arr.at[idx, sel].set(vals, mode="drop")
    return planes.node_write2(ec.shard, arr, idx, sel, vals, op=op)


def arb_winner(ec: EngineConfig, keys, prio_hi, prio_lo, active):
    """Per-key CAS arbitration (the RNIC's serialization of one round).

    Dense: global scatter-min (jnp plane) or the all-pairs arbitration
    kernel (Pallas planes) — same lexicographic-min winners bitwise.
    Sharded: each owner arbitrates its rows' contest locally and the
    won-bits combine in one exchange — bitwise the same winners (a key's
    contest happens entirely at its owner).
    """
    if ec.shard is None:
        return kops.cas_arbitrate(
            keys, prio_hi, prio_lo, active, ec.n_records, plane=ec.kernel_plane
        )
    return planes.node_cas_winner(
        ec.shard, ec.records_local, keys, prio_hi, prio_lo, active,
        kernel_plane=ec.kernel_plane,
    )


def scatter_ts_max(ec: EngineConfig, hi_arr, lo_arr, idx, ch, cl, active):
    """Lexicographic scatter-max of (ch, cl) timestamps into a store TS pair
    (MVCC rts bump, SUNDIAL lease renewal).  Owner-local when sharded: the
    candidate reduction runs over the local rows only."""
    if ec.shard is None:
        r, li, act = ec.n_records, idx, active
    else:
        r = ec.records_local
        li = planes.local_ix_drop(ec.shard, r, idx)
        act = active & (li < r)
    cand_hi = jnp.full((r,), -(2**31), jnp.int32).at[li].max(
        jnp.where(act, ch, -(2**31)), mode="drop"
    )
    at_max = act & (ch == cand_hi[jnp.clip(li, 0, r - 1)])
    cand_lo = jnp.full((r,), -(2**31), jnp.int32).at[li].max(
        jnp.where(at_max, cl, -(2**31)), mode="drop"
    )
    upd = (hi_arr < cand_hi) | ((hi_arr == cand_hi) & (lo_arr < cand_lo))
    return jnp.where(upd, cand_hi, hi_arr), jnp.where(upd, cand_lo, lo_arr)


def try_lock(ec: EngineConfig, store, st, op_mask, prio_hi, prio_lo, *, reentrant_ts=None):
    """Arbitrated CAS on lock words for ops in op_mask.

    Returns (won (N,K), store').  A CAS wins iff the lock is free (or held by
    this txn) and it is the per-key arbitration winner this round.  Sharded:
    the owner arbitrates + applies the CAS on its rows; the won-bits and the
    returned lock words are one batched reply exchange (os_cas semantics).
    """
    N, K = op_mask.shape
    keys_f = st["keys"].reshape(-1)
    active = op_mask.reshape(-1)
    win = arb_winner(ec, keys_f, prio_hi.reshape(-1), prio_lo.reshape(-1), active)
    lock_hi, lock_lo = read_rows_many(ec, (store["lock_hi"], store["lock_lo"]), st["keys"])
    lock = TS(lock_hi, lock_lo)
    mine = ts_eq(lock, TS(st["ts_hi"][:, None], st["ts_lo"][:, None]))
    free = ts_is_zero(lock) | mine
    won = win.reshape(N, K) & free & op_mask
    wf = won.reshape(-1)
    ts = txn_ts(st)
    new_hi = jnp.repeat(ts.hi, K)
    new_lo = jnp.repeat(ts.lo, K)
    store = dict(store)
    idx_w = jnp.where(wf, keys_f, ec.n_records)
    store["lock_hi"] = write_rows(ec, store["lock_hi"], idx_w, jnp.where(wf, new_hi, 0))
    store["lock_lo"] = write_rows(ec, store["lock_lo"], idx_w, jnp.where(wf, new_lo, 0))
    return won, store


def release_locks(ec: EngineConfig, store, st, rel_mask):
    """Zero lock words this txn holds for ops in rel_mask."""
    keys_f = st["keys"].reshape(-1)
    m = (rel_mask & st["locked"]).reshape(-1)
    store = dict(store)
    idx = jnp.where(m, keys_f, ec.n_records)
    store["lock_hi"] = write_rows(ec, store["lock_hi"], idx, 0)
    store["lock_lo"] = write_rows(ec, store["lock_lo"], idx, 0)
    return store


def finish_commit(ec: EngineConfig, cm: CostModel, st: Dict, mask) -> Dict:
    st = dict(st)
    st["n_commit"] = st["n_commit"] + mask.astype(jnp.int32)
    st["lat_sum"] = st["lat_sum"] + jnp.where(mask, st["lat_us"], 0.0)
    st["rt_sum"] = st["rt_sum"] + jnp.where(mask, st["rounds"].astype(jnp.float32), 0.0)
    if ec.history_cap:
        H = ec.history_cap
        offs = jnp.cumsum(mask.astype(jnp.int32)) - 1
        row = jnp.where(mask, st["h_idx"][0] + offs, H)  # drop when full
        row = jnp.where(row < H, row, H)
        st["h_keys"] = st["h_keys"].at[row].set(st["keys"], mode="drop")
        st["h_ver_r"] = st["h_ver_r"].at[row].set(st["ver_seen"], mode="drop")
        ver_w = st["ver_seen"] + st["is_w"].astype(jnp.int32)
        st["h_ver_w"] = st["h_ver_w"].at[row].set(ver_w, mode="drop")
        st["h_isw"] = st["h_isw"].at[row].set(st["is_w"], mode="drop")
        st["h_valid"] = st["h_valid"].at[row].set(st["valid"], mode="drop")
        st["h_ts_hi"] = st["h_ts_hi"].at[row].set(st["ts_hi"], mode="drop")
        st["h_ts_lo"] = st["h_ts_lo"].at[row].set(st["ts_lo"], mode="drop")
        st["h_idx"] = st["h_idx"] + mask.sum()[None].astype(jnp.int32)
    return st


def finish_abort(st: Dict, mask) -> Dict:
    st = dict(st)
    st["n_abort"] = st["n_abort"] + mask.astype(jnp.int32)
    return st


# ---------------------------------------------------------------------------
# Run loop + metrics
# ---------------------------------------------------------------------------


def run(
    protocol_tick,
    ec: EngineConfig,
    cm: CostModel,
    wl: Workload,
    n_ticks: int,
    warmup: int = 0,
    *,
    ticks_active=None,
):
    """Run the engine; returns (final_state, final_store, metrics dict).

    ``ticks_active`` (traced int32, None = unpadded) supports tick-axis
    bucketing (sweep.plan_buckets): the scan runs the padded ``n_ticks``
    shape but every tick past ``warmup + ticks_active`` freezes the whole
    carry — dead ticks touch no counter, no store word, no RNG draw — so
    the result is bitwise-equal to a run of exactly ``ticks_active`` ticks
    and a whole ticks sweep shares one compiled program.
    """
    from repro.core.registry import protocol_family

    # store layout is keyed by the registry FAMILY, so registered variants
    # (family="occ", ...) inherit the right metadata words
    store = init_store(
        protocol_family(ec.protocol), ec.records_local, wl.rw, wl.init_value,
        n_versions=ec.mvcc_slots,
    )
    st = init_state(ec, wl)

    def tick(carry, t):
        st0, store0 = carry
        st, store = protocol_tick(ec, cm, wl, st0, store0, t)
        st = dict(st)
        st["tick"] = st["tick"] + 1
        if ticks_active is not None:
            live = t < warmup + jnp.asarray(ticks_active, jnp.int32)

            def frz(new, old):
                return jnp.where(live, new, old)

            st = jax.tree_util.tree_map(frz, st, st0)
            store = jax.tree_util.tree_map(frz, store, store0)
        return (st, store), None

    if warmup:
        (st, store), _ = jax.lax.scan(tick, (st, store), jnp.arange(warmup))
        # reset counters after warmup
        for k in ("n_commit", "n_abort", "lat_sum", "rt_sum"):
            st[k] = jnp.zeros_like(st[k])
        st["stage_us"] = jnp.zeros_like(st["stage_us"])
    (st, store), _ = jax.lax.scan(tick, (st, store), jnp.arange(warmup, warmup + n_ticks))
    n_eff = n_ticks if ticks_active is None else ticks_active
    return st, store, summarize(ec, cm, st, n_eff)


def run_sharded(
    protocol_tick,
    ec: EngineConfig,
    cm: CostModel,
    wl: Workload,
    n_ticks: int,
    warmup: int = 0,
    *,
    devices: Optional[Sequence] = None,
    axis: str = "node",
):
    """:func:`run` with the simulated cluster laid out SPMD on a device mesh.

    The store (record data, locks, versions — the O(records) memory and
    compute) is sharded over a 1-D ``node`` mesh axis, whole simulated
    nodes per shard; the per-slot coordinator state is sequencer-replicated
    (O(slots·K) ints).  The protocol tick runs unchanged inside
    ``shard_map``: every store access routes through the planes.py
    transport (os_read / os_cas / capacity-ranking rounds as collectives),
    so integer commit/abort/round counters are bitwise-equal to the dense
    engine and the wire traffic is structurally honest — one exchange per
    network round.

    ``devices`` defaults to all of ``jax.devices()``; their count must
    divide ``ec.n_nodes`` so shards own whole nodes.  Returns the same
    (state, GLOBAL store, metrics) triple as :func:`run`.
    """
    from jax.sharding import PartitionSpec as P

    mesh, ec_sh = node_mesh_config(ec, devices, axis)

    def body():
        return run(protocol_tick, ec_sh, cm, wl, n_ticks, warmup=warmup)

    return planes.shard_map(
        body, mesh=mesh, in_specs=(), out_specs=(P(), P(axis), P()), check_rep=False
    )()


def node_mesh_config(ec: EngineConfig, devices: Optional[Sequence], axis: str):
    """Validate + build the 1-D node mesh and the sharded config.

    Shared by :func:`run_sharded` and CALVIN's epoch runner so the
    device-list defaulting, the whole-nodes-per-shard divisibility check,
    and the ``EngineConfig.shard`` wiring live in one place.
    """
    if ec.shard is not None:
        raise ValueError("node mesh: config already node-sharded")
    devices = list(devices) if devices is not None else list(jax.devices())
    n_shards = len(devices)
    if ec.n_nodes % n_shards:
        raise ValueError(
            f"node mesh: {n_shards} device(s) must divide n_nodes={ec.n_nodes} "
            "(shards own whole simulated nodes)"
        )
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(devices), (axis,))
    ec_sh = dataclasses.replace(ec, shard=NodeShard(axis=axis, n_shards=n_shards))
    return mesh, ec_sh


def summarize(ec: EngineConfig, cm: CostModel, st: Dict, n_ticks: int) -> Dict:
    commits = st["n_commit"].sum()
    aborts = st["n_abort"].sum()
    sim_us = n_ticks * cm.tick_us
    return {
        "commits": commits,
        "aborts": aborts,
        "throughput_mtps": commits / sim_us,  # million txns/sec (txns per us)
        "avg_latency_us": st["lat_sum"].sum() / jnp.maximum(commits, 1),
        "abort_rate": aborts / jnp.maximum(commits + aborts, 1),
        "avg_round_trips": st["rt_sum"].sum() / jnp.maximum(commits, 1),
        "stage_us_per_commit": st["stage_us"] / jnp.maximum(commits, 1),
    }
