"""Batched sweep engine (repro.core.sweep): batched-vs-sequential
equivalence, single-compilation guarantee, knob plumbing, and the
stage-graph runtime's pinned-golden / doorbell-merging guarantees."""
import json
import os

import numpy as np
import pytest

from repro.core import sweep
from repro.core.costmodel import N_HYBRID_STAGES, ONE_SIDED, RPC
from repro.core.sweep import all_hybrid_codes, grid_product, make_knobs, normalize_hybrid, run_grid

# tiny but contended: enough commits/aborts for the counters to be
# meaningfully compared, small enough that a grid run takes seconds
KW = dict(n_nodes=2, coroutines=8, records_per_node=128, ticks=64, warmup=8)
CODES = [0, 63, 0b010101, 0b101010]


def _run_cell(protocol, workload, hybrid, **kw):
    # import lazily: benchmarks/ is not an installed package, only reachable
    # when the repo root is on sys.path (conftest guarantees src/, CI runs
    # from the repo root)
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.common import run_cell

    m, _, _ = run_cell(protocol, workload, hybrid, **kw)
    return m


@pytest.mark.parametrize(
    "proto",
    [
        "nowait",
        "occ",
        # sundial is the slowest to compile sequentially; the exhaustive test
        # below spot-checks it in fast CI, the full sweep runs nightly
        pytest.param("sundial", marks=pytest.mark.slow),
    ],
)
def test_batched_matches_sequential(proto):
    rows = run_grid(proto, "smallbank", [{"hybrid": c} for c in CODES], **KW)
    for c, r in zip(CODES, rows):
        m = _run_cell(proto, "smallbank", c, **KW)
        # control flow is integer/bool-driven: counters must match exactly
        assert r["commits"] == m["commits"], (proto, c)
        assert r["aborts"] == m["aborts"], (proto, c)
        # float metrics may differ by fusion order only
        np.testing.assert_allclose(r["avg_latency_us"], m["avg_latency_us"], rtol=1e-4)
        np.testing.assert_allclose(
            r["stage_us_per_commit"], m["stage_us_per_commit"], rtol=1e-4, atol=1e-4
        )


@pytest.mark.slow  # 8 sequential reference compiles (~4 min); nightly CI
def test_knob_grid_matches_sequential():
    cfgs = grid_product(hybrid=[0, 63], hot_prob=[0.0, 0.9], seed=[0, 1])
    rows = run_grid("occ", "ycsb", cfgs, **KW)
    for cfg, r in zip(cfgs, rows):
        m = _run_cell(
            "occ", "ycsb", cfg["hybrid"], hot_prob=cfg["hot_prob"], seed=cfg["seed"], **KW
        )
        assert r["commits"] == m["commits"], cfg
        assert r["aborts"] == m["aborts"], cfg


def test_exhaustive_hybrid_single_compile():
    """The paper's 2^6 exhaustive coding sweep is ONE vmapped program."""
    before = sweep.compile_cache_size()
    kw = dict(n_nodes=2, coroutines=8, records_per_node=128, ticks=48, warmup=8)
    rows = run_grid("sundial", "smallbank", [{"hybrid": c} for c in all_hybrid_codes()], **kw)
    assert len(rows) == 2**N_HYBRID_STAGES
    assert all(r["commits"] > 0 for r in rows)
    assert all(np.isfinite(r["throughput_mtps"]) for r in rows)
    # a second grid over the same spec reuses the compiled program
    run_grid("sundial", "smallbank", [{"hybrid": 0b110011, "seed": 7}], **kw)
    after = sweep.compile_cache_size()
    if before >= 0 and after >= 0:  # introspection available
        assert after - before <= 2, (before, after)
    # codings 000000 and 111111 must match their sequential runs exactly
    for c in (0, 63):
        m = _run_cell("sundial", "smallbank", c, **kw)
        assert rows[c]["commits"] == m["commits"], c
        assert rows[c]["aborts"] == m["aborts"], c


def test_calvin_grid():
    rows = run_grid("calvin", "smallbank", [{"hybrid": 0}, {"hybrid": 63}], **KW)
    assert all(r["abort_rate"] == 0.0 for r in rows)
    assert rows[0]["commits"] == rows[1]["commits"]  # deterministic batch size
    m = _run_cell("calvin", "smallbank", (RPC,) * 6, **KW)
    assert rows[0]["commits"] == m["commits"]
    np.testing.assert_allclose(rows[0]["throughput_mtps"], m["throughput_mtps"], rtol=1e-4)


def test_stage_graph_pinned_golden_counters():
    """The stage-graph runtime (repro.core.rounds) reproduces the
    pre-refactor hand-rolled stage machines BITWISE: commit/abort counters
    for a pinned config grid were captured before the refactor
    (tests/data/stage_graph_golden.json) and must never drift."""
    path = os.path.join(os.path.dirname(__file__), "data", "stage_graph_golden.json")
    with open(path) as f:
        golden = json.load(f)
    for proto in ("nowait", "waitdie", "occ", "mvcc", "sundial"):
        rows = run_grid(proto, "smallbank", [{"hybrid": c} for c in CODES], **KW)
        for r in rows:
            g = golden[f"{proto}/smallbank/{r['hybrid']}"]
            assert int(r["commits"]) == g["commits"], (proto, r["hybrid"])
            assert int(r["aborts"]) == g["aborts"], (proto, r["hybrid"])
    for proto in ("nowait", "occ", "sundial", "mvcc"):
        (r,) = run_grid(proto, "ycsb", [{"hybrid": 0b010101}], **KW)
        g = golden[f"{proto}/ycsb/{r['hybrid']}"]
        assert int(r["commits"]) == g["commits"], (proto, "ycsb")
        assert int(r["aborts"]) == g["aborts"], (proto, "ycsb")


def test_doorbell_merging_fuses_log_commit():
    """Cross-stage doorbell merging (§4.2): with LOG+COMMIT both one-sided,
    merging collapses them into one posted round — write txns finish in
    fewer ticks (more commits) with fewer round trips; RPC codings are
    untouched; and a fused mixed coding beats both pure codings."""
    kw = dict(n_nodes=2, coroutines=12, records_per_node=4096, ticks=96, warmup=8)
    fused_code = (1 << 3) | (1 << 4)  # LOG + COMMIT one-sided, rest RPC
    codes = [0, 63, fused_code]
    plain = run_grid("sundial", "smallbank", [{"hybrid": c} for c in codes], **kw)
    merged = run_grid(
        "sundial", "smallbank", [{"hybrid": c} for c in codes], merge_stages=True, **kw
    )
    # pure RPC has no one-sided LOG/COMMIT: merging must be a no-op
    assert merged[0]["commits"] == plain[0]["commits"]
    assert merged[0]["aborts"] == plain[0]["aborts"]
    # fusable codings commit more and round-trip less
    for i in (1, 2):
        assert merged[i]["commits"] > plain[i]["commits"], codes[i]
        assert merged[i]["avg_round_trips"] < plain[i]["avg_round_trips"], codes[i]
    # a fused mixed coding beats BOTH pure codings (the §5 hybrid claim)
    pure_best = max(merged[0]["throughput_mtps"], merged[1]["throughput_mtps"])
    assert merged[2]["throughput_mtps"] > pure_best


def test_normalize_hybrid():
    assert normalize_hybrid(0) == (RPC,) * 6
    assert normalize_hybrid(63) == (ONE_SIDED,) * 6
    assert normalize_hybrid(0b000101) == (1, 0, 1, 0, 0, 0)  # bit i = stage i
    assert normalize_hybrid((1, 0, 1, 0, 0, 0)) == (1, 0, 1, 0, 0, 0)
    with pytest.raises(ValueError):
        normalize_hybrid((1, 0))


def test_make_knobs_defaults_and_validation():
    kn = make_knobs("ycsb", [{}, {"hot_prob": 0.5, "exec_ticks": 7}])
    assert kn.hybrid.shape == (2, N_HYBRID_STAGES)
    assert kn.exec_ticks.tolist() == [3, 7]  # ycsb default exec_ticks = 3
    np.testing.assert_allclose(kn.hot_prob[0], 0.10)
    with pytest.raises(TypeError):
        make_knobs("ycsb", [{"bogus": 1}])
    with pytest.raises(TypeError):  # hot_prob is ycsb-only, not silently ignored
        make_knobs("smallbank", [{"hot_prob": 0.5}])
