"""Kernel-plane parity contract (ISSUE 6, DESIGN.md §9): the Pallas plane
must reproduce the jnp plane's integer counters BITWISE for every protocol,
workload, and layout.  CI runs this with Pallas in interpret mode so the
kernel programs themselves are exercised on GPU-less runners.

Fast CI (-m "not slow") covers one protocol per hot-path family:
  nowait  -> lock_arbiter (CAS arbitration) + multi_read
  mvcc    -> mvcc_version_select (Cond R1/R2)
The nightly/schedule run adds the other four protocols, the ycsb workload,
and the node-sharded layout.
"""
import numpy as np
import pytest

from repro import api
from repro.kernels import ops

KW = dict(n_nodes=2, coroutines=6, records_per_node=64, ticks=32, warmup=4)
COUNTERS = ("commits", "aborts", "abort_rate", "throughput_mtps", "avg_round_trips")


def _rows(proto, workload, plane, **over):
    kw = dict(KW)
    kw.update(over)
    configs = kw.pop("configs", ({"hybrid": 0}, {"hybrid": 42}))
    spec = api.ExperimentSpec(
        protocol=proto, workload=workload, configs=tuple(configs), kernel_plane=plane, **kw
    )
    return api.execute(api.plan(spec)).rows


def _assert_parity(proto, workload, **over):
    jnp_rows = _rows(proto, workload, ops.JNP, **over)
    pal_rows = _rows(proto, workload, ops.PALLAS_INTERPRET, **over)
    assert len(jnp_rows) == len(pal_rows)
    for a, b in zip(jnp_rows, pal_rows):
        for k in COUNTERS:
            assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), (proto, workload, k)
    # sanity: the runs did real work, parity isn't vacuous 0 == 0
    assert sum(int(np.asarray(r["commits"]).sum()) for r in jnp_rows) > 0, proto


@pytest.mark.parametrize(
    "proto",
    ["nowait", "mvcc"]
    + [pytest.param(p, marks=pytest.mark.slow) for p in ("waitdie", "occ", "sundial", "calvin")],
)
def test_kernel_parity_smallbank(proto):
    _assert_parity(proto, "smallbank")


@pytest.mark.slow
@pytest.mark.parametrize("proto", ["mvcc", "sundial"])
def test_kernel_parity_ycsb(proto):
    _assert_parity(proto, "ycsb")


@pytest.mark.slow
def test_kernel_parity_node_sharded():
    """planes.node_cas_winner / node_read_batch under shard_map: the
    owner-local kernel work plus psum exchange must stay bitwise with jnp."""
    spec = dict(node_shards=1, layout="node")
    a = _rows("sundial", "smallbank", ops.JNP, configs=({"hybrid": 21},), **spec)
    b = _rows("sundial", "smallbank", ops.PALLAS_INTERPRET, configs=({"hybrid": 21},), **spec)
    for k in COUNTERS:
        assert np.array_equal(np.asarray(a[0][k]), np.asarray(b[0][k])), k


def test_plan_reports_kernel_plane():
    pl = api.plan(
        api.ExperimentSpec(
            protocol="nowait",
            workload="smallbank",
            configs=({"hybrid": 0},),
            kernel_plane=ops.PALLAS_INTERPRET,
            **KW,
        )
    )
    assert pl.kernel_plane == ops.PALLAS_INTERPRET
    s = pl.summary()
    assert "kernel plane" in s and ops.PALLAS_INTERPRET in s
