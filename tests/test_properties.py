"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")  # optional dev dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core.arbiter import scatter_min_winner
from repro.core.timestamps import TS, ts_lt, ts_max
from repro.sharding import AxisRules, merge_rules
from repro.workloads import make_workload
from jax.sharding import PartitionSpec as P

SET = settings(max_examples=25, deadline=None, derandomize=True)


@given(
    st.lists(st.tuples(st.integers(0, 7), st.integers(0, 100), st.booleans()), min_size=1, max_size=40)
)
@SET
def test_arbiter_unique_winner_per_key(reqs):
    keys = jnp.array([r[0] for r in reqs], jnp.int32)
    prio = jnp.array([r[1] for r in reqs], jnp.int32)
    active = jnp.array([r[2] for r in reqs], bool)
    lo = jnp.arange(len(reqs), dtype=jnp.int32)  # unique tiebreak
    won = scatter_min_winner(keys, prio, lo, active, 8)
    won = np.asarray(won)
    for k in range(8):
        mask = (np.asarray(keys) == k) & np.asarray(active)
        assert won[mask].sum() == (1 if mask.any() else 0)
        if mask.any():
            # the winner has the minimal (prio, lo) among active requests
            idx = np.where(mask)[0]
            best = min(idx, key=lambda i: (int(prio[i]), int(lo[i])))
            assert won[best]


@given(
    st.tuples(st.integers(0, 100), st.integers(1, 50)),
    st.tuples(st.integers(0, 100), st.integers(1, 50)),
    st.tuples(st.integers(0, 100), st.integers(1, 50)),
)
@SET
def test_timestamp_total_order(a, b, c):
    ta = TS(jnp.int32(a[0]), jnp.int32(a[1]))
    tb = TS(jnp.int32(b[0]), jnp.int32(b[1]))
    tc = TS(jnp.int32(c[0]), jnp.int32(c[1]))
    # antisymmetry + transitivity + max consistency
    assert not (bool(ts_lt(ta, tb)) and bool(ts_lt(tb, ta)))
    if bool(ts_lt(ta, tb)) and bool(ts_lt(tb, tc)):
        assert bool(ts_lt(ta, tc))
    m = ts_max(ta, tb)
    assert not bool(ts_lt(m, ta)) and not bool(ts_lt(m, tb))


@given(st.integers(0, 2**31 - 2), st.sampled_from(["smallbank", "ycsb", "tpcc"]))
@SET
def test_workload_txns_well_formed(seed, name):
    n_records = 512
    wl = make_workload(name, n_records)
    keys, is_w, valid = wl.gen(jax.random.PRNGKey(seed), jnp.int32(0), jnp.int32(seed % 40))
    keys, is_w, valid = np.asarray(keys), np.asarray(is_w), np.asarray(valid)
    assert ((keys >= 0) & (keys < n_records)).all()
    active_keys = keys[valid]
    assert len(set(active_keys.tolist())) == len(active_keys), "duplicate keys in txn"
    assert valid.any()
    assert (~is_w | valid).all(), "write op must be valid"


@given(st.integers(2, 16), st.integers(1, 8))
@SET
def test_sharding_resolver_divisibility(dim_mult, odd):
    """Resolved specs never shard a non-divisible dim; divisible dims shard."""
    import jax as _jax

    if len(_jax.devices()) != 1:
        return
    # fake mesh metadata path: resolver logic only needs axis sizes
    rules = merge_rules({})

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((4, 4))

    shd = AxisRules.__new__(AxisRules)
    shd.mesh = FakeMesh()
    shd.rules = rules
    shd.axis_sizes = {"data": 4, "model": 4}
    shd.has_pod = False
    spec = shd.resolve(P("batch", "heads"), (dim_mult * 4, odd))
    assert spec[0] == "data"
    if odd % 4 == 0:
        assert spec[1] == "model"
    else:
        assert spec[1] is None
