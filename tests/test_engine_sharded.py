"""Node-sharded engine (engine.run_sharded) SPMD equivalence (DESIGN.md §7).

The SIMULATED cluster runs with its ``n_nodes`` axis sharded over a device
mesh: store rows live on their owner shard and every remote access routes
through the planes.py transport.  The contract mirrors the sweep-engine
convention: integer/ratio metrics (commits, aborts, abort_rate,
throughput_mtps, avg_round_trips) are BITWISE-equal to the dense
single-device engine, final stores are bitwise-equal arrays, and the float
latency accumulations (avg_latency_us, stage_us_per_commit) are pinned to
1e-6 relative.

Like tests/test_sharded.py, the 4-fake-host equivalence run executes in a
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
(the main test process must keep seeing 1 device); direct in-process
variants run when the process already sees >= 2 devices (the CI spmd-test
job).  A 1-shard mesh variant runs everywhere: it exercises the full plane
transport (psum exchanges, owner-local arbitration) on any checkout.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.costmodel import ONE_SIDED, RPC, CostModel
from repro.core.engine import EngineConfig, run, run_sharded
from repro.core.protocols import PROTOCOLS, calvin as calvin_mod
from repro.workloads import make_workload

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SLOT_PROTOS = ("nowait", "waitdie", "occ", "mvcc", "sundial")
# a genuinely mixed coding so both communication planes execute
MIXED = (ONE_SIDED, RPC, ONE_SIDED, RPC, ONE_SIDED, RPC)
BITWISE = ("commits", "aborts", "abort_rate", "throughput_mtps", "avg_round_trips")
ULP = ("avg_latency_us", "stage_us_per_commit")


def _truncate_gen(gen, k):
    def g(key, node, slot):
        keys, is_w, valid = gen(key, node, slot)
        return keys[:k], is_w[:k], valid[:k]

    return g


def _setup(proto, workload, history_cap=0):
    ec = EngineConfig(
        protocol=proto, n_nodes=4, coroutines=6, records_per_node=64,
        rw=2, max_ops=2, hybrid=MIXED, history_cap=history_cap,
    )
    if workload == "ycsb":
        # 4-op txns + moderate hot_prob: full 16-op ycsb livelocks the 2PL
        # family to 0 commits at this tiny scale (see test_oracle)
        wl = make_workload("ycsb", ec.n_records, hot_prob=0.15)
        wl = wl._replace(max_ops=4, gen=_truncate_gen(wl.gen, 4))
    else:
        wl = make_workload(workload, ec.n_records)
    ec = EngineConfig(**{**ec.__dict__, "rw": wl.rw, "max_ops": wl.max_ops})
    return ec, wl


def assert_equiv(m_ref, m_sh, store_ref, store_sh, tag):
    for k in BITWISE:
        assert np.array_equal(np.asarray(m_ref[k]), np.asarray(m_sh[k])), (tag, k)
    for k in ULP:
        np.testing.assert_allclose(
            np.asarray(m_sh[k]), np.asarray(m_ref[k]), rtol=1e-6, err_msg=f"{tag}:{k}"
        )
    for k in store_ref:
        assert np.array_equal(np.asarray(store_ref[k]), np.asarray(store_sh[k])), (tag, k)


def _run_pair(proto, workload, devices, history_cap=0, ticks=48, warmup=8):
    ec, wl = _setup(proto, workload, history_cap=history_cap)
    cm = CostModel()
    if proto == "calvin":
        store_r, m_r = jax.jit(lambda: calvin_mod.run_epochs(ec, cm, wl, 10))()
        store_s, m_s = jax.jit(
            lambda: calvin_mod.run_epochs_sharded(ec, cm, wl, 10, devices=devices)
        )()
        return None, store_r, m_r, None, store_s, m_s
    tick = PROTOCOLS[proto].tick
    st_r, store_r, m_r = jax.jit(lambda: run(tick, ec, cm, wl, ticks, warmup=warmup))()
    st_s, store_s, m_s = jax.jit(
        lambda: run_sharded(tick, ec, cm, wl, ticks, warmup=warmup, devices=devices)
    )()
    return st_r, store_r, m_r, st_s, store_s, m_s


# ---------------------------------------------------------------------------
# 1-shard mesh: the plane transport on any checkout (no fake hosts needed)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("proto", SLOT_PROTOS + ("calvin",))
def test_single_shard_mesh_matches_dense(proto):
    """A 1-device node mesh still runs the full sharded program (shard_map,
    psum exchanges, owner-local arbitration) and must reproduce the dense
    engine bitwise."""
    devices = [jax.devices()[0]]
    _, store_r, m_r, _, store_s, m_s = _run_pair(proto, "smallbank", devices)
    assert int(np.asarray(m_r["commits"])) > 0
    assert_equiv(m_r, m_s, store_r, store_s, f"{proto}/1shard")


def test_run_sharded_rejects_non_dividing_mesh():
    ec, wl = _setup("occ", "smallbank")
    devs = [jax.devices()[0]] * 3  # 3 never divides n_nodes=4
    with pytest.raises(ValueError, match="divide n_nodes"):
        run_sharded(PROTOCOLS["occ"].tick, ec, CostModel(), wl, 8, devices=devs)


# ---------------------------------------------------------------------------
# multi-device direct variants (CI spmd-test job: 4 forced fake hosts)
# ---------------------------------------------------------------------------

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2, reason="needs >=2 devices (CI spmd-test forces 4 fake hosts)"
)


@multi_device
@pytest.mark.parametrize("workload", ["smallbank", "ycsb"])
@pytest.mark.parametrize("proto", SLOT_PROTOS + ("calvin",))
def test_sharded_engine_matches_dense_direct(proto, workload):
    n_dev = len(jax.devices())
    devices = jax.devices()[: 4 if n_dev >= 4 else 2]
    _, store_r, m_r, _, store_s, m_s = _run_pair(proto, workload, devices)
    assert int(np.asarray(m_r["commits"])) > 0
    assert_equiv(m_r, m_s, store_r, store_s, f"{proto}/{workload}")


@multi_device
def test_sharded_oracle_replay():
    """A sharded run's committed history replays to its final store: the
    serializability oracle holds THROUGH the collective transport."""
    from repro.core.protocols import occ
    from repro.core.validate import final_data, inflight_commit_writes, replay_committed

    ec, wl = _setup("occ", "smallbank", history_cap=4096)
    devices = jax.devices()[: 4 if len(jax.devices()) >= 4 else 2]
    st, store, m = jax.jit(
        lambda: run_sharded(
            PROTOCOLS["occ"].tick, ec, CostModel(), wl, 96, devices=devices
        )
    )()
    commits = int(np.asarray(m["commits"]))
    assert commits > 30
    assert int(np.asarray(st["h_idx"])[0]) == commits
    replay = replay_committed(st, wl, ec.n_records)
    final = final_data(store)
    keep = np.ones(ec.n_records, bool)
    keep[inflight_commit_writes(st, occ.S_COMMIT)] = False
    mismatch = (replay[keep] != final[keep]).any(axis=-1).sum()
    assert mismatch == 0, f"{mismatch} records diverge from serial replay"


@multi_device
def test_grid_on_2d_config_node_mesh():
    """run_grid_sharded(node_shards=K) reshapes the devices into a 2-D
    ``config × node`` mesh: the config axis splits over one factor while
    each config's simulation runs node-sharded over the other — bitwise
    the single-device grid."""
    from repro.core.sweep import run_grid, run_grid_sharded

    n_dev = len(jax.devices())
    node_shards = 2 if n_dev % 2 == 0 else n_dev
    kw = dict(n_nodes=4, coroutines=6, records_per_node=64, ticks=48, warmup=8)
    cfgs = [{"hybrid": c, "seed": i} for i, c in enumerate((0, 21, 42, 63, 7))]
    ref = run_grid("occ", "smallbank", cfgs, **kw)
    out = run_grid_sharded("occ", "smallbank", cfgs, node_shards=node_shards, **kw)
    assert out[0]["n_node_shards"] == node_shards
    for r, s in zip(ref, out):
        for k in BITWISE:
            assert np.array_equal(np.asarray(r[k]), np.asarray(s[k])), (k, r["hybrid"])
        for k in ULP:
            np.testing.assert_allclose(np.asarray(s[k]), np.asarray(r[k]), rtol=1e-6)


@multi_device
def test_run_cell_sharded_compiles_once_per_mesh():
    """Knobs stay traced through the node-sharded cell runner: hybrids and
    seeds at a fixed (spec, mesh) share one compiled SPMD program."""
    from repro.core import sweep

    kw = dict(n_nodes=4, coroutines=6, records_per_node=64, ticks=32, warmup=4)
    before = sweep.node_sharded_compile_count()
    m1 = sweep.run_cell_sharded("sundial", "smallbank", {"hybrid": 21}, **kw)
    m2 = sweep.run_cell_sharded("sundial", "smallbank", {"hybrid": 42, "seed": 3}, **kw)
    after = sweep.node_sharded_compile_count()
    assert m1["commits"] > 0 and m2["commits"] > 0
    if before >= 0 and after >= 0:
        assert after - before == 1, "node-sharded runner recompiled per config"


# ---------------------------------------------------------------------------
# subprocess variant: keeps single-device checkouts honest (nightly)
# ---------------------------------------------------------------------------

_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax
from repro.core.costmodel import ONE_SIDED, RPC, CostModel
from repro.core.engine import EngineConfig, run, run_sharded
from repro.core.protocols import PROTOCOLS, calvin as calvin_mod
from repro.workloads import make_workload

assert len(jax.devices()) == 4, jax.devices()
MIXED = (ONE_SIDED, RPC, ONE_SIDED, RPC, ONE_SIDED, RPC)
BITWISE = ("commits", "aborts", "abort_rate", "throughput_mtps", "avg_round_trips")
ULP = ("avg_latency_us", "stage_us_per_commit")

for workload in ("smallbank", "ycsb"):
    for proto in ("nowait", "waitdie", "occ", "mvcc", "sundial", "calvin"):
        ec = EngineConfig(protocol=proto, n_nodes=4, coroutines=6,
                          records_per_node=64, rw=2, max_ops=2, hybrid=MIXED)
        if workload == "ycsb":
            wl = make_workload("ycsb", ec.n_records, hot_prob=0.15)
            g = wl.gen
            wl = wl._replace(max_ops=4, gen=lambda key, node, slot, g=g: tuple(
                a[:4] for a in g(key, node, slot)))
        else:
            wl = make_workload(workload, ec.n_records)
        ec = EngineConfig(**{**ec.__dict__, "rw": wl.rw, "max_ops": wl.max_ops})
        cm = CostModel()
        if proto == "calvin":
            store_r, m_r = jax.jit(lambda: calvin_mod.run_epochs(ec, cm, wl, 10))()
            store_s, m_s = jax.jit(lambda: calvin_mod.run_epochs_sharded(ec, cm, wl, 10))()
        else:
            t = PROTOCOLS[proto].tick
            _, store_r, m_r = jax.jit(lambda: run(t, ec, cm, wl, 48, warmup=8))()
            _, store_s, m_s = jax.jit(lambda: run_sharded(t, ec, cm, wl, 48, warmup=8))()
        assert int(np.asarray(m_r["commits"])) > 0, (proto, workload)
        for k in BITWISE:
            assert np.array_equal(np.asarray(m_r[k]), np.asarray(m_s[k])), (proto, workload, k)
        for k in ULP:
            np.testing.assert_allclose(np.asarray(m_s[k]), np.asarray(m_r[k]),
                                       rtol=1e-6, err_msg=f"{proto}/{workload}:{k}")
        for k in store_r:
            assert np.array_equal(np.asarray(store_r[k]), np.asarray(store_s[k])), (proto, workload, k)
print("NODE SHARDED ENGINE OK")
"""


@pytest.mark.slow  # ~3 min; the CI spmd-test job covers the same ground
# in-process on every PR via the direct variants above
@pytest.mark.skipif(
    len(jax.devices()) >= 2,
    reason="redundant when the process already sees multiple devices",
)
def test_sharded_engine_subprocess_all_protocols():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _CODE], capture_output=True, text=True, env=env, timeout=540
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "NODE SHARDED ENGINE OK" in out.stdout
