"""The repro.api front door (ISSUE 5): planner semantics, registry plugin
surface, deprecation shims, and the api-vs-legacy equivalence contract.

Equivalence convention (memory/DESIGN.md §6): integer counters bitwise,
float metrics are fine here because the shims DELEGATE to plan/execute —
identical compiled programs — but we assert bitwise on counters only to
stay within the documented contract.
"""
import subprocess
import sys
import warnings

import numpy as np
import pytest

from repro import api
from repro.core import registry, sweep

KW = dict(n_nodes=2, coroutines=8, records_per_node=128, ticks=48, warmup=8)
COUNTERS = ("commits", "aborts", "abort_rate", "throughput_mtps", "avg_round_trips")


def _spec(proto, configs, **over):
    kw = dict(KW)
    kw.update(over)
    return api.ExperimentSpec(protocol=proto, workload="smallbank", configs=tuple(configs), **kw)


def _legacy(call, *args, **kw):
    """Run a legacy shim with its DeprecationWarning silenced."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return call(*args, **kw)


# ---------------------------------------------------------------------------
# api vs legacy equivalence: all six protocols through plan/execute
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "proto",
    ["nowait", "occ", "calvin"]
    + [pytest.param(p, marks=pytest.mark.slow) for p in ("waitdie", "mvcc", "sundial")],
)
def test_api_matches_legacy_run_grid(proto):
    cfgs = [{"hybrid": 0}, {"hybrid": 63}]
    rows_api = api.execute(api.plan(_spec(proto, cfgs))).rows
    rows_legacy = _legacy(sweep.run_grid, proto, "smallbank", cfgs, **KW)
    for a, b in zip(rows_api, rows_legacy):
        for k in COUNTERS:
            assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), (proto, k)
        assert a["hybrid"] == b["hybrid"]


def test_api_node_layout_matches_legacy_cell():
    spec = _spec("sundial", [{"hybrid": 21}], node_shards=1)
    m_api = api.execute(api.plan(spec)).row
    m_legacy = _legacy(
        sweep.run_cell_sharded, "sundial", "smallbank", {"hybrid": 21}, node_shards=1, **KW
    )
    for k in COUNTERS:
        assert np.array_equal(np.asarray(m_api[k]), np.asarray(m_legacy[k])), k
    assert m_api["n_node_shards"] == m_legacy["n_node_shards"] == 1


def test_api_sharded_matches_legacy_sharded():
    cfgs = [{"hybrid": 21}, {"hybrid": 42}]
    rows_api = api.run(_spec("nowait", cfgs, devices="auto")).rows
    rows_legacy = _legacy(sweep.run_grid_sharded, "nowait", "smallbank", cfgs, **KW)
    for a, b in zip(rows_api, rows_legacy):
        for k in COUNTERS:
            assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), k
        assert a["n_devices"] == b["n_devices"]


# ---------------------------------------------------------------------------
# deprecation shims: exactly one warning each, naming the replacement
# ---------------------------------------------------------------------------


def _dep_warnings(w):
    return [x for x in w if issubclass(x.category, DeprecationWarning) and "repro.api" in str(x.message)]


def test_legacy_entry_points_warn_once_each():
    cfgs = [{"hybrid": 21}]
    calls = [
        ("run_grid", lambda: sweep.run_grid("nowait", "smallbank", cfgs, **KW)),
        ("run_grid_sharded", lambda: sweep.run_grid_sharded("nowait", "smallbank", cfgs, **KW)),
        (
            "run_cell_sharded",
            lambda: sweep.run_cell_sharded("nowait", "smallbank", cfgs[0], node_shards=1, **KW),
        ),
    ]
    for name, call in calls:
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            out = call()
        assert out, name
        dep = _dep_warnings(w)
        assert len(dep) == 1, (name, [str(x.message) for x in dep])
        assert name in str(dep[0].message)


# ---------------------------------------------------------------------------
# planner semantics
# ---------------------------------------------------------------------------


def test_plan_dense_single_bucket():
    pl = api.plan(_spec("occ", [{"hybrid": 0}, {"hybrid": 63}]))
    assert pl.layout == api.DENSE
    assert pl.devices is None and pl.node_shards is None
    assert len(pl.buckets) == 1 and pl.expected_compiles == 1
    assert pl.cache == "grid"
    s = pl.summary()
    assert "occ" in s and "bucket 0" in s and "dense" in s and "expected compiles" in s


def test_plan_buckets_static_axes_and_summary():
    pl = api.plan(
        _spec("occ", [{"hybrid": 0, "coroutines": 4}, {"hybrid": 0, "coroutines": 20}])
    )
    # pow2 buckets: ceil(4)=4, ceil(20)=32 -> two shape buckets, two compiles
    assert pl.expected_compiles == 2
    assert [pb.grid_spec.coroutines for pb in pl.buckets] == [4, 20]
    assert "2" in pl.summary().splitlines()[-1]


def test_plan_auto_layout_from_devices():
    import jax

    pl = api.plan(_spec("occ", [{"hybrid": 0}], devices="auto"))
    if len(jax.devices()) == 1:
        assert pl.layout == api.DENSE and pl.n_devices == 1
    else:
        assert pl.layout == api.CONFIG and pl.n_devices == len(jax.devices())


def test_plan_node_layout_requires_single_config():
    with pytest.raises(ValueError, match="ONE config"):
        api.plan(_spec("occ", [{"hybrid": 0}, {"hybrid": 1}], layout="node"))
    with pytest.raises(ValueError, match="static axes"):
        api.plan(_spec("occ", [{"hybrid": 0, "coroutines": 4}], layout="node"))


def test_plan_resolves_and_reports_kernel_plane():
    import jax

    from repro.kernels import ops

    # default "auto" resolves by backend: jnp on CPU, pallas on tpu/gpu
    pl = api.plan(_spec("occ", [{"hybrid": 0}]))
    expect = ops.PALLAS if jax.default_backend() in ("tpu", "gpu") else ops.JNP
    assert pl.kernel_plane == expect
    # an explicit plane is honoured and named in the summary
    pl = api.plan(_spec("occ", [{"hybrid": 0}], kernel_plane="pallas_interpret"))
    assert pl.kernel_plane == "pallas_interpret"
    s = pl.summary()
    assert "kernel plane" in s and "pallas_interpret" in s


def test_plan_rejects_bad_kernel_plane():
    with pytest.raises(ValueError, match="kernel_plane"):
        api.plan(_spec("occ", [{"hybrid": 0}], kernel_plane="cuda"))


def test_plan_rejects_empty_and_bad_layout():
    with pytest.raises(ValueError, match="at least one"):
        api.plan(_spec("occ", []))
    with pytest.raises(ValueError, match="valid layouts"):
        api.plan(_spec("occ", [{}], layout="banana"))
    with pytest.raises(ValueError, match="'auto'"):
        api.plan(_spec("occ", [{}], devices="all-of-them"))


def test_results_row_guard():
    r = api.Results(rows=[{"a": 1}, {"a": 2}])
    with pytest.raises(ValueError, match="2 rows"):
        _ = r.row


# ---------------------------------------------------------------------------
# registry misuse: actionable errors naming the registry API
# ---------------------------------------------------------------------------


def test_unknown_protocol_names_registry_api():
    with pytest.raises(KeyError, match="register_protocol"):
        registry.get_protocol("nope")
    with pytest.raises(KeyError, match="register_protocol"):
        api.plan(_spec("nope", [{}]))


def test_duplicate_registration_is_actionable():
    occ = registry.get_protocol("occ")
    registry.register_protocol("scratch-occ", tick=occ.tick, stages=occ.stages)
    try:
        with pytest.raises(ValueError, match="already registered.*override=True"):
            registry.register_protocol("scratch-occ", tick=occ.tick, stages=occ.stages)
        # override + unregister are the documented escape hatches
        registry.register_protocol("scratch-occ", tick=occ.tick, stages=occ.stages, override=True)
    finally:
        registry.unregister_protocol("scratch-occ")
    assert "scratch-occ" not in registry.protocol_names()
    with pytest.raises(KeyError, match="unknown protocol"):
        registry.unregister_protocol("scratch-occ")


def test_register_validates_tick_and_hooks():
    with pytest.raises(ValueError, match="tick-driven"):
        registry.register_protocol("scratch-bad", tick=None)
    with pytest.raises(ValueError, match="RunHooks"):
        registry.register_protocol(
            "scratch-bad", tick=None, capabilities=registry.Caps(tick_driven=False)
        )
    assert "scratch-bad" not in registry.protocol_names()


def test_capability_violating_plan_node_sharding_calvin():
    # 2-D config x node mesh for CALVIN: the canonical capability violation
    with pytest.raises(ValueError, match="batch_node_shardable.*register_protocol"):
        api.plan(_spec("calvin", [{"hybrid": 0}, {"hybrid": 63}], node_shards=2))
    # the sweep-internal dispatch path raises the same class of error
    with pytest.raises(ValueError, match="batch_node_shardable"):
        _legacy(
            sweep.run_grid,
            "calvin",
            "smallbank",
            [{"hybrid": 0}, {"hybrid": 63}],
            node_shards=2,
            devices=[None, None],  # placeholder devices; caps checked first
            **KW,
        )


def test_registry_view_and_variants():
    from repro.core.protocols import PROTOCOLS

    names = registry.protocol_names()
    assert names[:2] == ("nowait", "waitdie") and "calvin" in names
    # the legacy mapping shape still works, backed by the registry
    assert PROTOCOLS["occ"].tick is registry.get_protocol("occ").tick
    assert set(PROTOCOLS) == set(names)
    # nowait/waitdie are twopl variants: explicit flag + shared runtime family
    assert registry.get_protocol("nowait").variant == {"wait_die": False}
    assert registry.get_protocol("waitdie").variant == {"wait_die": True}
    assert registry.get_protocol("nowait").family == "twopl"
    assert registry.get_protocol("waitdie").family == "twopl"
    assert registry.protocol_family("occ") == "occ"  # default: own name
    # capability flags drive the planner instead of name checks
    assert registry.get_protocol("calvin").caps.batch_node_shardable is False
    assert registry.get_protocol("calvin").caps.tick_driven is False
    assert registry.get_protocol("mvcc").caps.ro_commit is True


def test_plugin_protocol_runs_through_front_door():
    """A registered protocol is immediately runnable via plan/execute —
    'a new protocol is one file + one register call'.  family= keys the
    name-keyed runtime tables (store layout, wire costs, merge pairs) so a
    variant inherits its base protocol's data layout."""
    occ = registry.get_protocol("occ")
    registry.register_protocol(
        "occ-clone", tick=occ.tick, stages=occ.stages, capabilities=occ.caps, family="occ"
    )
    try:
        rows = api.run(_spec("occ-clone", [{"hybrid": 21}])).rows
        ref = api.run(_spec("occ", [{"hybrid": 21}])).rows
        assert rows[0]["commits"] == ref[0]["commits"]
        assert rows[0]["aborts"] == ref[0]["aborts"]
    finally:
        registry.unregister_protocol("occ-clone")


# ---------------------------------------------------------------------------
# the API boundary gate holds (same check CI's lint job runs)
# ---------------------------------------------------------------------------


def test_api_boundary_gate_clean():
    import os

    root = os.path.join(os.path.dirname(__file__), "..")
    out = subprocess.run(
        [sys.executable, os.path.join(root, "scripts", "check_api_boundary.py")],
        capture_output=True,
        text=True,
    )
    assert out.returncode == 0, out.stdout + out.stderr


def test_kernel_dead_module_gate(tmp_path):
    """kernel_liveness flags modules nothing imports, follows transitive
    imports through live kernel modules, and exempts __init__/ref."""
    import importlib.util
    import os

    root = os.path.join(os.path.dirname(__file__), "..")
    spec = importlib.util.spec_from_file_location(
        "check_api_boundary", os.path.join(root, "scripts", "check_api_boundary.py")
    )
    gate = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(gate)

    kdir = tmp_path / "src" / "repro" / "kernels"
    kdir.mkdir(parents=True)
    (kdir / "__init__.py").write_text("")
    (kdir / "ref.py").write_text("")  # exempt: the oracle set
    (kdir / "ops.py").write_text("from repro.kernels.alive import f\n")
    (kdir / "alive.py").write_text("def f():\n    def g():\n        pass\n")
    (kdir / "vestigial.py").write_text("def unused():\n    pass\n")
    eng = tmp_path / "src" / "repro" / "core"
    eng.mkdir(parents=True)
    # lazy function-level import still counts (AST walk, not module top only)
    (eng / "engine.py").write_text(
        "def tick():\n    from repro.kernels import ops as kops\n    return kops\n"
    )
    bad = gate.kernel_liveness(root=str(tmp_path))
    assert len(bad) == 1 and "vestigial.py" in bad[0] and "dead kernel module" in bad[0]
    # deleting the vestigial module makes the tree clean
    (kdir / "vestigial.py").unlink()
    assert gate.kernel_liveness(root=str(tmp_path)) == []
    # the real repo is clean too
    assert gate.kernel_liveness() == []
