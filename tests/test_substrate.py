"""Data pipeline / checkpoint / fault-tolerance / optimizer tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import reduced_config
from repro.data.pipeline import make_pipeline, shard_for_host
from repro.ft.runner import TrainRunner
from repro.models.lm import init_lm
from repro.sharding import AxisRules, unzip_params
from repro.train.steps import build_train_step


def test_pipeline_deterministic_and_restartable():
    init, nxt = make_pipeline(vocab=97, batch=4, seq=16, seed=3)
    s = init()
    s1, b1 = nxt(s)
    s2, b2 = nxt(s1)
    # restart from the same state reproduces the stream exactly
    s1b, b1b = nxt(init())
    assert bool((b1["tokens"] == b1b["tokens"]).all())
    _, b2b = nxt(s1b)
    assert bool((b2["tokens"] == b2b["tokens"]).all())
    assert not bool((b1["tokens"] == b2["tokens"]).all())
    # host sharding partitions the batch
    h0 = shard_for_host(b1, 0, 2)
    h1 = shard_for_host(b1, 1, 2)
    assert bool((jnp.concatenate([h0["tokens"], h1["tokens"]]) == b1["tokens"]).all())


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": [jnp.ones((4,), jnp.bfloat16), {"c": jnp.int32(7)}],
    }
    save_checkpoint(str(tmp_path), 5, tree)
    save_checkpoint(str(tmp_path), 9, tree)
    assert latest_step(str(tmp_path)) == 9
    step, back = restore_checkpoint(str(tmp_path), tree)
    assert step == 9
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_prunes(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2 and steps[-1] == "step_00000005"


def test_training_failure_recovery_identical_stream(tmp_path):
    """Crash + restore must land on the same loss trajectory (exact data
    stream resume) as an uninterrupted run."""
    cfg = reduced_config("stablelm-1.6b")
    shd = AxisRules(None)
    train_step, opt = build_train_step(cfg, shd, "adamw")
    jitted = jax.jit(train_step, donate_argnums=(0, 1))

    def init_state():
        params = unzip_params(init_lm(jax.random.PRNGKey(0), cfg, jnp.float32))[0]
        return params, opt.init(params)

    init_data, nxt = make_pipeline(cfg.vocab_size, 4, 32, seed=1)

    out_clean = TrainRunner(jitted, init_state, nxt, init_data).run(14, log_every=1000)
    out_fail = TrainRunner(
        jitted, init_state, nxt, init_data,
        ckpt_dir=str(tmp_path), ckpt_every=5, fail_at=9,
    ).run(14, log_every=1000)
    # the last losses must match exactly: same params, same data stream
    assert abs(out_clean["losses"][-1] - out_fail["losses"][-1]) < 1e-5


def test_elastic_remesh_restore(tmp_path):
    """Elastic scaling: checkpoint saved under one mesh restores onto a
    different mesh shape (subprocess with 8 forced host devices)."""
    import os as _os
    import subprocess
    import sys

    root = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.checkpoint import save_checkpoint, restore_checkpoint

tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
mesh_a = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
sharded = jax.device_put(tree["w"], NamedSharding(mesh_a, P("data", "model")))
save_checkpoint(r"{tmp_path}", 1, {{"w": sharded}})

mesh_b = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
step, back = restore_checkpoint(
    r"{tmp_path}", {{"w": tree["w"]}},
    shardings={{"w": NamedSharding(mesh_b, P("data", "model"))}},
)
assert step == 1
assert (np.asarray(back["w"]) == np.asarray(tree["w"])).all()
assert back["w"].sharding.mesh.devices.shape == (4, 2)
print("REMESH OK")
"""
    env = dict(_os.environ)
    env["PYTHONPATH"] = _os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=300
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "REMESH OK" in out.stdout


def test_gradient_compression_error_feedback():
    """int8 + error feedback converges like the uncompressed optimizer."""
    from repro.optim.compression import dequantize_int8, quantize_int8

    # quantize/dequantize roundtrip bound
    g = jax.random.normal(jax.random.PRNGKey(0), (257,)) * 3.0
    q, s = quantize_int8(g)
    assert q.dtype == jnp.int8
    assert float(jnp.abs(dequantize_int8(q, s) - g).max()) <= float(s) * 0.5 + 1e-6

    cfg = reduced_config("stablelm-1.6b")
    shd = AxisRules(None)
    losses = {}
    for compress in (False, True):
        train_step, opt = build_train_step(cfg, shd, "adamw")
        from repro.optim.compression import with_error_feedback as wef

        opt2 = wef(opt, enabled=compress)

        def step_fn(params, state, i, b, _opt=opt2):
            # rebuild train step around the wrapped optimizer
            from repro.models.lm import lm_loss

            loss, grads = jax.value_and_grad(lambda p: lm_loss(p, cfg, shd, b))(params)
            params, state, gn = _opt.update(grads, state, params, i)
            return params, state, loss

        params = unzip_params(init_lm(jax.random.PRNGKey(0), cfg, jnp.float32))[0]
        state = opt2.init(params)
        init_data, nxt = make_pipeline(cfg.vocab_size, 4, 32, seed=0)
        ds = init_data()
        jstep = jax.jit(step_fn)
        ls = []
        for i in range(10):
            ds, b = nxt(ds)
            params, state, loss = jstep(params, state, jnp.int32(i), b)
            ls.append(float(loss))
        losses[compress] = ls
    assert losses[True][-1] < losses[True][0]
    # compressed trajectory tracks the exact one closely
    assert abs(losses[True][-1] - losses[False][-1]) < 0.15


def test_optimizers_reduce_loss():
    cfg = reduced_config("stablelm-1.6b")
    shd = AxisRules(None)
    for name in ("adamw", "momentum_bf16"):
        train_step, opt = build_train_step(cfg, shd, name)
        params = unzip_params(init_lm(jax.random.PRNGKey(0), cfg, jnp.float32))[0]
        state = opt.init(params)
        init_data, nxt = make_pipeline(cfg.vocab_size, 4, 32, seed=0)
        ds = init_data()
        losses = []
        step_fn = jax.jit(train_step)
        # fixed batch: per-batch sampling noise on random data would swamp
        # the few-step improvement; memorizing one batch is deterministic
        ds, b = nxt(ds)
        for i in range(12):
            params, state, m = step_fn(params, state, jnp.int32(i), b)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], (name, losses)
