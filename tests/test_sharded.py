"""Device-sharded sweep engine (sweep.run_grid_sharded) SPMD equivalence.

The main test process must keep seeing 1 device (tests/conftest.py), so the
4-fake-device equivalence run executes in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` — the same forcing
the CI ``spmd-test`` job applies process-wide.  Direct (non-subprocess)
variants below run only when the current process already sees >= 2 devices
(i.e. inside that CI job or on real multi-device hosts).

Equivalence contract (pinned here and documented in DESIGN.md §6):
every integer / ratio metric (commits, aborts, abort_rate,
throughput_mtps, avg_round_trips) is BITWISE-equal to the single-device
``run_grid``; ``avg_latency_us`` and ``stage_us_per_commit`` involve
float32 cross-slot accumulations whose lowering may differ between the
partitioned and unpartitioned programs, and are pinned to 1e-6 relative.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BITWISE = ("commits", "aborts", "abort_rate", "throughput_mtps", "avg_round_trips")
ULP = ("avg_latency_us", "stage_us_per_commit")


def assert_rows_equal(ref, got):
    assert len(ref) == len(got)
    for r, s in zip(ref, got):
        for k in BITWISE:
            assert np.array_equal(np.asarray(r[k]), np.asarray(s[k])), (k, r["hybrid"])
        for k in ULP:
            np.testing.assert_allclose(np.asarray(s[k]), np.asarray(r[k]), rtol=1e-6, err_msg=k)


_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax
from repro.core import sweep
from repro.core.sweep import all_hybrid_codes, run_grid, run_grid_sharded

assert len(jax.devices()) == 4, jax.devices()
KW = dict(n_nodes=2, coroutines=8, records_per_node=128, ticks=48, warmup=8)
BITWISE = ("commits", "aborts", "abort_rate", "throughput_mtps", "avg_round_trips")
ULP = ("avg_latency_us", "stage_us_per_commit")

def check(ref, got):
    for r, s in zip(ref, got):
        for k in BITWISE:
            assert np.array_equal(np.asarray(r[k]), np.asarray(s[k])), (k, r["hybrid"])
        for k in ULP:
            np.testing.assert_allclose(np.asarray(s[k]), np.asarray(r[k]), rtol=1e-6, err_msg=k)

# the paper's 2^6 hybrid enumeration, 64 configs over 4 devices
cfgs = [{"hybrid": c} for c in all_hybrid_codes()]
ref = run_grid("occ", "smallbank", cfgs, **KW)
sh = run_grid_sharded("occ", "smallbank", cfgs, **KW)
assert sh[0]["n_devices"] == 4 and all(r["commits"] > 0 for r in sh)
check(ref, sh)

# non-divisible grid: 6 configs on 4 devices (remainder-padded, pad dropped)
cfgs6 = [{"hybrid": c, "seed": i} for i, c in enumerate((0, 1, 5, 21, 42, 63))]
check(run_grid("occ", "smallbank", cfgs6, **KW),
      run_grid_sharded("occ", "smallbank", cfgs6, **KW))

# sharding composes with bucketed static-axis padding
cfgb = [{"hybrid": 21, "coroutines": 5}, {"hybrid": 42, "coroutines": 8},
        {"hybrid": 63, "coroutines": 7}]
ref_b = run_grid("occ", "smallbank", cfgb, **KW)
sh_b = run_grid_sharded("occ", "smallbank", cfgb, **KW)
assert sh_b[0]["n_buckets"] == 1
check(ref_b, sh_b)
print("SPMD SWEEP OK")
"""


@pytest.mark.slow  # ~1.5 min; the CI spmd-test job covers the same ground
# on every PR via the in-process variants below, this subprocess version
# keeps single-device checkouts honest nightly
@pytest.mark.skipif(
    len(jax.devices()) >= 2,
    reason="redundant when the process already sees multiple devices: the "
    "direct variants below cover the same equivalence in-process",
)
def test_sharded_grid_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _CODE], capture_output=True, text=True, env=env, timeout=540
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SPMD SWEEP OK" in out.stdout


multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2, reason="needs >=2 devices (CI spmd-test job forces 4 fake hosts)"
)


@multi_device
def test_sharded_direct_hybrid_grid():
    """Direct in-process variant for the 4-fake-device CI job."""
    from repro.core.sweep import all_hybrid_codes, run_grid, run_grid_sharded

    kw = dict(n_nodes=2, coroutines=8, records_per_node=128, ticks=48, warmup=8)
    cfgs = [{"hybrid": c} for c in all_hybrid_codes()]
    ref = run_grid("occ", "smallbank", cfgs, **kw)
    sh = run_grid_sharded("occ", "smallbank", cfgs, **kw)
    assert sh[0]["n_devices"] == len(jax.devices())
    assert_rows_equal(ref, sh)


@multi_device
def test_sharded_direct_bucketed_composition():
    """Sharding composes with bucketed static-axis padding."""
    from repro.core.sweep import run_grid, run_grid_sharded

    kw = dict(n_nodes=2, coroutines=8, records_per_node=128, ticks=48, warmup=8)
    cfgs = [
        {"hybrid": 21, "coroutines": 5},
        {"hybrid": 42, "coroutines": 8},
        {"hybrid": 63, "coroutines": 7},
    ]
    ref = run_grid("occ", "smallbank", cfgs, **kw)
    sh = run_grid_sharded("occ", "smallbank", cfgs, **kw)
    assert sh[0]["n_buckets"] == 1
    assert_rows_equal(ref, sh)


@multi_device
def test_sharded_direct_non_divisible():
    from repro.core.sweep import run_grid, run_grid_sharded

    kw = dict(n_nodes=2, coroutines=8, records_per_node=128, ticks=48, warmup=8)
    n_dev = len(jax.devices())
    cfgs = [{"hybrid": c, "seed": c} for c in range(n_dev + 1)]  # never divides (n_dev >= 2)
    assert_rows_equal(
        run_grid("nowait", "smallbank", cfgs, **kw),
        run_grid_sharded("nowait", "smallbank", cfgs, **kw),
    )


def test_sharded_single_device_is_run_grid():
    """With one device the sharded entry point must not recompile or pad —
    it IS run_grid (same compiled program, same counters)."""
    from repro.core import sweep
    from repro.core.sweep import run_grid, run_grid_sharded

    if len(jax.devices()) != 1:
        pytest.skip("single-device contract")
    kw = dict(n_nodes=2, coroutines=8, records_per_node=128, ticks=48, warmup=8)
    cfgs = [{"hybrid": 21}, {"hybrid": 42}]
    ref = run_grid("nowait", "smallbank", cfgs, **kw)
    before = sweep.sharded_compile_cache_size()
    sh = run_grid_sharded("nowait", "smallbank", cfgs, **kw)
    after = sweep.sharded_compile_cache_size()
    if before >= 0 and after >= 0:
        assert after == before  # never touched the sharded entry point
    for r, s in zip(ref, sh):
        assert r["commits"] == s["commits"] and r["aborts"] == s["aborts"]
        assert s["n_devices"] == 1
