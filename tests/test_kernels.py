"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lock_arbiter import lock_arbiter
from repro.kernels.mvcc_version_select import mvcc_version_select
from repro.kernels.rglru_scan import rglru_scan

KEY = jax.random.PRNGKey(42)


@pytest.mark.parametrize("B,H,S,Dh", [(1, 2, 128, 64), (2, 1, 192, 32), (1, 1, 320, 128)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, S, Dh, causal, dtype):
    k1, k2, k3 = jax.random.split(jax.random.fold_in(KEY, S * Dh + causal), 3)
    q = jax.random.normal(k1, (B, H, S, Dh), dtype)
    k = jax.random.normal(k2, (B, H, S, Dh), dtype)
    v = jax.random.normal(k3, (B, H, S, Dh), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    exp = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("M", [7, 256, 700])
def test_mvcc_version_select(M):
    ks = [jax.random.fold_in(KEY, M * 10 + i) for i in range(6)]
    wh = jax.random.randint(ks[0], (M, 4), 0, 6)
    wl = jax.random.randint(ks[1], (M, 4), 0, 4)
    ch = jax.random.randint(ks[2], (M,), 0, 7)
    cl = jax.random.randint(ks[3], (M,), 0, 4)
    lh = jax.random.randint(ks[4], (M,), 0, 3)
    ll = jax.random.randint(ks[5], (M,), 0, 2)
    f1, s1, o1 = mvcc_version_select(wh, wl, ch, cl, lh, ll)
    f2, s2, o2 = ref.mvcc_version_select_ref(wh, wl, ch, cl, lh, ll)
    assert bool((f1 == f2).all()) and bool((o1 == o2).all())
    assert bool(jnp.where(f2, s1 == s2, True).all())


@pytest.mark.parametrize("G,M,nk", [(2, 32, 4), (4, 128, 11), (1, 256, 40)])
def test_lock_arbiter(G, M, nk):
    ks = [jax.random.fold_in(KEY, G * M + i) for i in range(3)]
    keys = jax.random.randint(ks[0], (G, M), 0, nk)
    prio = jax.random.randint(ks[1], (G, M), 0, 1000)
    act = jax.random.uniform(ks[2], (G, M)) < 0.6
    block = max(128, 1 << (M - 1).bit_length())
    won = lock_arbiter(keys, prio, act, block_m=block)
    exp = ref.lock_arbiter_ref(keys, prio, act)
    assert bool((won == exp).all())
    # exactly one winner per active key per group
    for g in range(G):
        seen = {}
        for i in range(M):
            if bool(act[g, i]):
                seen.setdefault(int(keys[g, i]), 0)
                seen[int(keys[g, i])] += int(won[g, i])
        assert all(v == 1 for v in seen.values())


@pytest.mark.parametrize("B,T,W", [(1, 64, 128), (2, 300, 256), (1, 128, 8)])
def test_rglru_scan(B, T, W):
    ks = [jax.random.fold_in(KEY, T * W + i) for i in range(3)]
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, W)))
    b = jax.random.normal(ks[1], (B, T, W)) * 0.1
    h0 = jax.random.normal(ks[2], (B, W))
    out = rglru_scan(a, b, h0, block_t=64)
    exp = ref.rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5)
