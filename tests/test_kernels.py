"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode).

The lock_arbiter / mvcc_version_select property tests follow the
tests/test_bucketed.py convention: Hypothesis when installed, a
derandomized seeded generator otherwise (the container CI image has no
hypothesis), so the properties are exercised either way.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lock_arbiter import lock_arbiter
from repro.kernels.multi_read import multi_read
from repro.kernels.mvcc_version_select import mvcc_version_select

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

KEY = jax.random.PRNGKey(42)


@pytest.mark.parametrize("B,H,S,Dh", [(1, 2, 128, 64), (2, 1, 192, 32), (1, 1, 320, 128)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, S, Dh, causal, dtype):
    k1, k2, k3 = jax.random.split(jax.random.fold_in(KEY, S * Dh + causal), 3)
    q = jax.random.normal(k1, (B, H, S, Dh), dtype)
    k = jax.random.normal(k2, (B, H, S, Dh), dtype)
    v = jax.random.normal(k3, (B, H, S, Dh), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    exp = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("M,S", [(7, 4), (256, 4), (700, 4), (64, 2), (96, 6)])
def test_mvcc_version_select(M, S):
    ks = [jax.random.fold_in(KEY, M * 10 + S + i) for i in range(6)]
    wh = jax.random.randint(ks[0], (M, S), 0, 6)
    wl = jax.random.randint(ks[1], (M, S), 0, 4)
    ch = jax.random.randint(ks[2], (M,), 0, 7)
    cl = jax.random.randint(ks[3], (M,), 0, 4)
    lh = jax.random.randint(ks[4], (M,), 0, 3)
    ll = jax.random.randint(ks[5], (M,), 0, 2)
    f1, s1, o1 = mvcc_version_select(wh, wl, ch, cl, lh, ll, interpret=True)
    f2, s2, o2 = ref.mvcc_version_select_ref(wh, wl, ch, cl, lh, ll)
    assert bool((f1 == f2).all()) and bool((o1 == o2).all())
    assert bool((s1 == s2).all())  # unfound rows argmax to slot 0 in both


def _arbiter_case(G, M, nk, seed):
    """Random arbitration batch with UNIQUE (hi, lo) pairs per group (the
    engine's contract: ts pairs, or hashed hi + unique logical op index lo)."""
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, nk, (G, M)).astype(np.int32)
    hi = rng.integers(0, 5, (G, M)).astype(np.int32)  # narrow: force lo tiebreaks
    lo = np.stack([rng.permutation(M) for _ in range(G)]).astype(np.int32)
    act = rng.random((G, M)) < 0.6
    return jnp.asarray(keys), jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(act)


@pytest.mark.parametrize("G,M,nk", [(2, 32, 4), (4, 128, 11), (1, 256, 40)])
def test_lock_arbiter(G, M, nk):
    keys, hi, lo, act = _arbiter_case(G, M, nk, seed=G * M + nk)
    won = lock_arbiter(keys, hi, lo, act, interpret=True)
    exp = ref.lock_arbiter_ref(keys, hi, lo, act)
    assert bool((won == exp).all())


def _check_arbiter_properties(seed: int):
    """The two lock_arbiter properties: exactly one winner per active key
    per owner group, and padding-invariance (extra inactive tail entries
    never change the live prefix's winners)."""
    rng = np.random.default_rng(seed)
    G = int(rng.integers(1, 4))
    M = int(rng.integers(5, 200))
    nk = int(rng.integers(2, 30))
    keys, hi, lo, act = _arbiter_case(G, M, nk, seed)
    won = np.asarray(lock_arbiter(keys, hi, lo, act, interpret=True))
    # exactly one winner per distinct active key per group
    for g in range(G):
        for k in set(np.asarray(keys)[g][np.asarray(act)[g]].tolist()):
            contenders = (np.asarray(keys)[g] == k) & np.asarray(act)[g]
            assert won[g][contenders].sum() == 1, (g, k)
        assert not won[g][~np.asarray(act)[g]].any()
    # padding-invariance: a bigger tile (inactive tail) gives the same winners
    pad = int(rng.integers(1, 64))
    kp = jnp.pad(keys, ((0, 0), (0, pad)), constant_values=-1)
    hp = jnp.pad(hi, ((0, 0), (0, pad)))
    lp = jnp.pad(lo, ((0, 0), (0, pad)))
    ap = jnp.pad(act, ((0, 0), (0, pad)))
    won_p = np.asarray(lock_arbiter(kp, hp, lp, ap, interpret=True))
    assert (won_p[:, :M] == won).all() and not won_p[:, M:].any()


def _np_version_oracle(wh, wl, ch, cl, lh, ll):
    """Numpy Cond R1/R2 oracle, written independently of the jnp reference:
    per row, scan the slots for the lexicographically largest (wh, wl)
    strictly below (ch, cl), skipping empty (0, 0) slots; R2 = lock free or
    lock after ctts."""
    M, S = wh.shape
    found = np.zeros(M, bool)
    slot = np.zeros(M, np.int32)
    for i in range(M):
        best = None
        for s in range(S):
            v = (int(wh[i, s]), int(wl[i, s]))
            if v == (0, 0) or v >= (int(ch[i]), int(cl[i])):
                continue
            if best is None or v > best:
                best, found[i], slot[i] = v, True, s
    ok = ((lh == 0) & (ll == 0)) | (ch < lh) | ((ch == lh) & (cl < ll))
    return found, slot, ok


def _check_version_select(seed: int):
    rng = np.random.default_rng(seed)
    M = int(rng.integers(1, 400))
    S = int(rng.integers(2, 6))
    wh = rng.integers(0, 5, (M, S)).astype(np.int32)
    wl = rng.integers(0, 4, (M, S)).astype(np.int32)
    ch = rng.integers(0, 6, M).astype(np.int32)
    cl = rng.integers(0, 4, M).astype(np.int32)
    lh = rng.integers(0, 3, M).astype(np.int32)
    ll = rng.integers(0, 2, M).astype(np.int32)
    f, s, o = mvcc_version_select(*map(jnp.asarray, (wh, wl, ch, cl, lh, ll)), interpret=True)
    ef, es, eo = _np_version_oracle(wh, wl, ch, cl, lh, ll)
    assert (np.asarray(f) == ef).all() and (np.asarray(o) == eo).all()
    assert (np.asarray(s)[ef] == es[ef]).all()


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None, derandomize=True)
    @given(st.integers(0, 2**31 - 1))
    def test_lock_arbiter_properties(seed):
        _check_arbiter_properties(seed)

    @settings(max_examples=10, deadline=None, derandomize=True)
    @given(st.integers(0, 2**31 - 1))
    def test_version_select_vs_numpy_oracle(seed):
        _check_version_select(seed)

else:

    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
    def test_lock_arbiter_properties(seed):
        _check_arbiter_properties(seed)

    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
    def test_version_select_vs_numpy_oracle(seed):
        _check_version_select(seed)


@pytest.mark.parametrize("R,A,M", [(64, 3, 40), (500, 7, 129), (128, 1, 1000)])
def test_multi_read(R, A, M):
    ks = [jax.random.fold_in(KEY, R * A + M + i) for i in range(2)]
    table = jax.random.randint(ks[0], (R, A), -(2**28), 2**28, dtype=jnp.int32)
    keys = jax.random.randint(ks[1], (M,), 0, R, dtype=jnp.int32)
    out = multi_read(table, keys, block_m=64, block_r=128, interpret=True)
    assert bool((out == table[keys]).all())
    # large int32 values survive exactly (no f32 rounding above 2^24)
    big = jnp.full((R, A), 2**30 - 7, jnp.int32)
    out = multi_read(big, keys, interpret=True)
    assert bool((out == 2**30 - 7).all())


def test_multi_read_padding_keys_gather_zero():
    table = jnp.arange(12, dtype=jnp.int32).reshape(6, 2) + 1
    keys = jnp.asarray([0, -1, 5, -1], jnp.int32)
    out = multi_read(table, keys, interpret=True)
    exp = ref.multi_read_ref(table, keys)
    assert bool((out == exp).all())
    assert not np.asarray(out)[1].any() and not np.asarray(out)[3].any()
