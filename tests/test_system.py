"""End-to-end behaviour tests for the RCC system (paper-level claims)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.core.costmodel import ONE_SIDED, RPC, CostModel
from repro.core.engine import EngineConfig, run
from repro.core.protocols import PROTOCOLS
from repro.workloads import make_workload

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _metrics(proto, prim, **kw):
    defaults = dict(n_nodes=4, coroutines=40, records_per_node=2048, rw=2, max_ops=2)
    defaults.update(kw)
    wl_name = defaults.pop("workload", "smallbank")
    hot = defaults.pop("hot_prob", None)
    ec = EngineConfig(protocol=proto, hybrid=(prim,) * 6, **defaults)
    wlkw = {"hot_prob": hot} if hot is not None else {}
    wl = make_workload(wl_name, ec.n_records, **wlkw)
    ec = EngineConfig(
        protocol=proto, hybrid=(prim,) * 6,
        **{**defaults, "rw": wl.rw, "max_ops": wl.max_ops},
    )
    _, _, m = jax.jit(lambda: run(PROTOCOLS[proto].tick, ec, CostModel(), wl, 200, warmup=40))()
    return {k: float(jnp.asarray(v).sum()) if hasattr(v, "shape") else v for k, v in m.items()}


@pytest.mark.slow  # ~1.5 min: six full contention runs; nightly CI runs it
def test_occ_degrades_most_under_contention():
    """Paper Fig. 8: OCC throughput drops hardest as contention rises."""
    drops = {}
    for proto in ("occ", "mvcc", "sundial"):
        lo = _metrics(proto, ONE_SIDED, workload="ycsb", hot_prob=0.0, records_per_node=1024)
        hi = _metrics(proto, ONE_SIDED, workload="ycsb", hot_prob=0.9, records_per_node=1024)
        drops[proto] = hi["throughput_mtps"] / max(lo["throughput_mtps"], 1e-9)
    assert drops["occ"] <= drops["mvcc"] + 0.05
    assert drops["occ"] <= drops["sundial"] + 0.05


def test_rpc_suffers_under_handler_load():
    """Paper Fig. 6/9: one-sided outperforms RPC when the remote CPU is busy."""
    rpc = _metrics("nowait", RPC, coroutines=80)
    os_ = _metrics("nowait", ONE_SIDED, coroutines=80)
    assert os_["throughput_mtps"] >= rpc["throughput_mtps"]
    assert os_["avg_latency_us"] < rpc["avg_latency_us"]


def test_dryrun_results_all_green():
    """The shipped multi-pod dry-run record: every non-skip cell compiled."""
    path = os.path.join(ROOT, "dryrun_results.json")
    if not os.path.exists(path):
        pytest.skip("dryrun_results.json not generated yet")
    with open(path) as f:
        recs = json.load(f)
    assert len(recs) == 80  # 10 archs x 4 shapes x 2 meshes
    bad = [r for r in recs if r["status"] == "error"]
    assert not bad, [(r["arch"], r["shape"], r["mesh"], r["error"]) for r in bad]
    meshes = {r["mesh"] for r in recs if r["status"] == "ok"}
    assert meshes == {"16x16", "2x16x16"}


def test_spmd_planes_multidevice():
    """One-sided/two-sided planes over an 8-device mesh (subprocess: the
    main test process must keep seeing 1 device)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.planes import make_planes

n_nodes, rpn, rw = 8, 16, 2
mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("node",))
os_read, os_cas, rpc_call = make_planes(mesh, "node", rpn, rw)
data = jnp.arange(n_nodes * rpn * rw, dtype=jnp.int32).reshape(n_nodes * rpn, rw)
keys = jnp.array([0, 17, 33, 120, 5, 99, 64, 127], jnp.int32)
vals = jax.jit(os_read)(data, keys)
exp = data[keys]
assert (vals == exp).all(), (vals, exp)
locks = jnp.zeros((n_nodes * rpn,), jnp.int32)
keys2 = jnp.array([3, 3, 3, 40, 40, 7, 8, 9], jnp.int32)
new = jnp.arange(1, 9, dtype=jnp.int32)
locks2, won = jax.jit(os_cas)(locks, keys2, new)
won = np.asarray(won)
assert won.sum() == 5, won  # one winner per distinct key {3,40,7,8,9}
for k in (3, 40, 7, 8, 9):
    assert won[np.asarray(keys2) == k].sum() == 1
print("SPMD PLANES OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=300
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SPMD PLANES OK" in out.stdout
