"""Cross-protocol serializability oracle (final-state equivalence).

Replays each protocol's COMMITTED transactions in commit order against a
plain sequential store (validate.replay_committed) and asserts the result
equals the engine store's latest committed record values
(validate.final_data) — for all six protocols on smallbank and ycsb.  This
is stronger than the precedence-graph acyclicity check: it catches wrong
*values* (lost writes, stale reads feeding read-modify-writes), not just
wrong orderings, so bigger sweep machinery (bucketing, sharding) cannot
silently drift from correct transaction semantics.

Also pins CALVIN's determinism contract: a permuted node numbering (the
record blocks of the partitioned store relabeled by a permutation) yields
bitwise-identical commit counters — no aborts by construction — and a
block-permuted final store.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.costmodel import ONE_SIDED, RPC, CostModel
from repro.core.engine import EngineConfig, run
from repro.core.protocols import PROTOCOLS, calvin as calvin_mod, mvcc, occ, sundial, twopl
from repro.core.validate import final_data, inflight_commit_writes, replay_committed
from repro.workloads import make_workload

SLOT_PROTOS = ("nowait", "waitdie", "occ", "mvcc", "sundial")
COMMIT_STAGE = {
    "nowait": twopl.S_COMMIT,
    "waitdie": twopl.S_COMMIT,
    "occ": occ.S_COMMIT,
    "mvcc": mvcc.S_COMMIT,
    "sundial": sundial.S_COMMIT,
}
# a genuinely mixed coding so both communication planes execute
MIXED = (ONE_SIDED, RPC, ONE_SIDED, RPC, ONE_SIDED, RPC)


def _truncate_gen(gen, k):
    def g(key, node, slot):
        keys, is_w, valid = gen(key, node, slot)
        return keys[:k], is_w[:k], valid[:k]

    return g


def _setup(proto, workload, hybrid=MIXED):
    ec = EngineConfig(
        protocol=proto, n_nodes=2, coroutines=8, records_per_node=64,
        rw=2, max_ops=2, hybrid=hybrid, history_cap=4096,
    )
    if workload == "ycsb":
        # NOWAIT/WAITDIE starve outright at hot_prob 0.5 on this tiny hot
        # set (0 commits — the paper's 2PL-under-contention cliff); the
        # oracle needs committed history, not a starvation benchmark
        hot = 0.15 if proto in ("nowait", "waitdie") else 0.5
        wl = make_workload("ycsb", ec.n_records, hot_prob=hot)
        wl = wl._replace(max_ops=4, gen=_truncate_gen(wl.gen, 4))
    else:
        wl = make_workload(workload, ec.n_records)
    ec = EngineConfig(**{**ec.__dict__, "rw": wl.rw, "max_ops": wl.max_ops})
    return ec, wl


@pytest.mark.parametrize("workload", ["smallbank", "ycsb"])
@pytest.mark.parametrize("proto", SLOT_PROTOS)
def test_final_state_equals_commit_order_replay(proto, workload):
    ec, wl = _setup(proto, workload)
    st, store, m = jax.jit(lambda: run(PROTOCOLS[proto].tick, ec, CostModel(), wl, 96))()
    commits = int(np.asarray(m["commits"]))
    assert commits > 30, m  # the oracle needs a real history
    # every commit produced exactly one history row (no overflow, no drops)
    assert int(np.asarray(st["h_idx"])[0]) == commits
    replay = replay_committed(st, wl, ec.n_records)
    final = final_data(store)
    # transactions caught mid-commit at the cutoff have partial writes in
    # the store but no history row; exclude exactly those keys
    keep = np.ones(ec.n_records, bool)
    keep[inflight_commit_writes(st, COMMIT_STAGE[proto])] = False
    mismatch = (replay[keep] != final[keep]).any(axis=-1).sum()
    assert mismatch == 0, f"{proto}/{workload}: {mismatch} records diverge from serial replay"


def test_smallbank_total_balance_accounted():
    """Transfers conserve the total; single-account writes deposit exactly
    +1 — so the replayed total equals init + committed deposit count, a
    value-level invariant the replay oracle inherits from the workload."""
    ec, wl = _setup("occ", "smallbank")
    st, store, _ = jax.jit(lambda: run(PROTOCOLS["occ"].tick, ec, CostModel(), wl, 96))()
    replay = replay_committed(st, wl, ec.n_records)
    n = int(np.asarray(st["h_idx"])[0])
    isw, valid = np.asarray(st["h_isw"])[:n], np.asarray(st["h_valid"])[:n]
    deposits = (isw[:, 0] & valid[:, 0] & ~valid[:, 1]).sum()
    assert replay.sum() == ec.n_records * wl.rw * wl.init_value + deposits


# ---------------------------------------------------------------------------
# CALVIN: deterministic execution + permutation symmetry
# ---------------------------------------------------------------------------


def _calvin_ec(coroutines=8):
    return EngineConfig(
        protocol="calvin", n_nodes=4, coroutines=coroutines, records_per_node=64,
        rw=2, max_ops=2, hybrid=(RPC,) * 6,
    )


@pytest.mark.parametrize("workload", ["smallbank", "ycsb"])
def test_calvin_final_state_equals_sequential_replay(workload):
    """CALVIN's vectorized lock-free execution == a plain numpy interpreter
    of the agreed deterministic schedule (epoch, then dependency wave, each
    wave reading the pre-wave snapshot).  Catches vectorization bugs in the
    jax wave executor against readable reference semantics."""
    ec = _calvin_ec()
    if workload == "ycsb":
        wl = make_workload("ycsb", ec.n_records, hot_prob=0.5)
        wl = wl._replace(max_ops=4, gen=_truncate_gen(wl.gen, 4))
        ec = EngineConfig(**{**ec.__dict__, "rw": wl.rw, "max_ops": wl.max_ops})
    else:
        wl = make_workload(workload, ec.n_records)
    n_epochs = 12
    cm = CostModel()
    store, m = jax.jit(lambda: calvin_mod.run_epochs(ec, cm, wl, n_epochs))()
    assert float(m["abort_rate"]) == 0.0
    key0 = jax.random.PRNGKey(ec.seed)
    epoch_txns = jax.jit(lambda e: calvin_mod._epoch_txns(ec, wl, e, key0))
    data = np.full((ec.n_records, wl.rw), wl.init_value, np.int32)
    for epoch in range(n_epochs):
        keys, is_w, valid, _ = epoch_txns(jnp.int32(epoch))
        wave = np.asarray(calvin_mod._waves(ec, keys, is_w, valid))
        keys, is_w, valid = np.asarray(keys), np.asarray(is_w), np.asarray(valid)
        for w in range(int(wave.max()) + 1):
            snap = data.copy()  # every wave-w txn reads the pre-wave state
            for s in np.where(wave == w)[0]:
                wv = np.asarray(wl.execute(
                    jnp.asarray(keys[s]), jnp.asarray(is_w[s]),
                    jnp.asarray(valid[s]), jnp.asarray(snap[keys[s]]),
                ))
                eff = is_w[s] & valid[s]
                data[keys[s][eff]] = wv[eff]
    assert (np.asarray(store["data"]) == data).all(), "CALVIN diverges from serial replay"


def test_calvin_node_permutation_determinism():
    """Same seed under a permuted node numbering (record blocks relabeled
    by a permutation of the nodes) yields bitwise-identical commit
    counters — CALVIN commits every transaction of every epoch by
    construction — and a block-permuted final store."""
    ec = _calvin_ec()
    wl = make_workload("smallbank", ec.n_records)
    cm = CostModel()
    n_epochs = 16
    store_a, m_a = jax.jit(lambda: calvin_mod.run_epochs(ec, cm, wl, n_epochs))()

    perm = jnp.asarray([2, 0, 3, 1], jnp.int32)  # node relabeling
    rpn = ec.records_per_node

    def permuted_gen(key, node, slot, base=wl.gen):
        keys, is_w, valid = base(key, node, slot)
        return perm[keys // rpn] * rpn + keys % rpn, is_w, valid

    wl_p = wl._replace(gen=permuted_gen)
    store_b, m_b = jax.jit(lambda: calvin_mod.run_epochs(ec, cm, wl_p, n_epochs))()

    # pinned: no aborts, every slot commits once per epoch, bitwise equal
    assert int(np.asarray(m_a["commits"])) == n_epochs * ec.n_slots
    assert int(np.asarray(m_a["aborts"])) == 0 and int(np.asarray(m_b["aborts"])) == 0
    assert int(np.asarray(m_a["commits"])) == int(np.asarray(m_b["commits"]))
    assert float(m_a["abort_rate"]) == float(m_b["abort_rate"]) == 0.0
    # the permuted run IS the original with record blocks relabeled
    blocks_a = np.asarray(store_a["data"]).reshape(ec.n_nodes, rpn, wl.rw)
    blocks_b = np.asarray(store_b["data"]).reshape(ec.n_nodes, rpn, wl.rw)
    assert (blocks_b[np.asarray(perm)] == blocks_a).all()
