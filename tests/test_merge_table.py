"""Cross-stage doorbell merge table (rounds.MERGE_TABLE, DESIGN.md §4).

PR 2 hardcoded one fusable pair (LOG rides COMMIT); the table generalizes
it to ordered (absorber, absorbed) pairs with per-transaction precedence.
These tests pin the routing semantics directly — the benchmark rows in
``hybrid_search.py`` only *print* the gain, so a silent regression in the
pair predicates or the write-only fall-through would otherwise pass CI.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import rounds
from repro.core.costmodel import ST_COMMIT, ST_LOG, ST_VALIDATE
from repro.core.engine import EngineConfig
from repro.core.sweep import run_grid

KW = dict(n_nodes=2, coroutines=8, records_per_node=128, ticks=64, warmup=8)

# validate(2) + log(3) one-sided, COMMIT two-sided: only the VALIDATE
# doorbell can absorb the LOG round
VL_ONLY = 0b001100


def _hy(code):
    return tuple((code >> i) & 1 for i in range(6))


def _st(valid, is_w):
    return {"valid": jnp.asarray(valid, bool), "is_w": jnp.asarray(is_w, bool)}


def _ec(protocol, code, merge=True):
    return EngineConfig(protocol=protocol, hybrid=_hy(code), merge_stages=merge)


def test_log_rides_per_txn_precedence():
    """VALIDATE claims a validating txn's LOG; a write-only txn (no read
    set -> no validate round) falls through to the COMMIT doorbell; with
    merging off nothing absorbs."""
    st = _st([[True, True], [True, True]], [[False, True], [True, True]])
    all_os = (1 << ST_VALIDATE) | (1 << ST_LOG) | (1 << ST_COMMIT)
    absorbed, by_v, by_c = rounds.log_rides(_ec("occ", all_os), st)
    # txn 0 reads+writes: validate absorbs; txn 1 write-only: commit absorbs
    assert np.asarray(by_v).tolist() == [True, False]
    assert np.asarray(by_c).tolist() == [False, True]
    assert np.asarray(absorbed).all()
    # COMMIT two-sided: the write-only txn has NO ride -> real LOG round
    absorbed, by_v, by_c = rounds.log_rides(_ec("occ", VL_ONLY), st)
    assert np.asarray(by_v).tolist() == [True, False]
    assert not np.asarray(by_c).any()
    assert np.asarray(absorbed).tolist() == [True, False]
    # merge_stages off: scalar False everywhere (the pre-merge program)
    absorbed, _, _ = rounds.log_rides(_ec("occ", all_os, merge=False), st)
    assert not np.asarray(absorbed).any()


def test_default_table_has_no_validate_pair():
    """Protocols on the default table (sundial/mvcc/twopl) must not grow a
    VALIDATE absorber: VL_ONLY codings fuse nothing for them."""
    st = _st([[True, True]], [[False, True]])
    absorbed, by_v, by_c = rounds.log_rides(_ec("sundial", VL_ONLY), st)
    assert not np.asarray(absorbed).any()
    assert not np.asarray(by_v).any() and not np.asarray(by_c).any()
    assert ("occ" in rounds.MERGE_TABLE) and (ST_VALIDATE, ST_LOG) in rounds.merge_pairs("occ")
    assert rounds.merge_pairs("sundial") == ((ST_COMMIT, ST_LOG),)


def test_occ_validate_log_fusion_changes_schedule_sundial_does_not():
    """End to end: at VL_ONLY, merging changes occ's execution (the LOG
    round is skipped for validating writers) but leaves sundial's
    bitwise-untouched (no registered pair fires)."""
    occ_off = run_grid("occ", "smallbank", [{"hybrid": VL_ONLY}], **KW)[0]
    occ_on = run_grid("occ", "smallbank", [{"hybrid": VL_ONLY}], merge_stages=True, **KW)[0]
    # the fused schedule is a different execution (the per-commit round
    # ratio may move either way as the conflict mix shifts), but the saved
    # LOG round must show up as lower commit latency
    assert occ_on["avg_latency_us"] < occ_off["avg_latency_us"]
    assert (occ_on["commits"], occ_on["aborts"]) != (occ_off["commits"], occ_off["aborts"])
    sun_off = run_grid("sundial", "smallbank", [{"hybrid": VL_ONLY}], **KW)[0]
    sun_on = run_grid("sundial", "smallbank", [{"hybrid": VL_ONLY}], merge_stages=True, **KW)[0]
    for k in ("commits", "aborts", "avg_round_trips", "avg_latency_us"):
        assert np.array_equal(np.asarray(sun_off[k]), np.asarray(sun_on[k])), k


def test_ro_commit_flag_is_mvccs_fast_path():
    """The declarative RO fast path is a table entry on mvcc's RTS stage."""
    from repro.core.protocols import mvcc

    rts = next(s for s in mvcc.SPECS if s.stage == mvcc.S_RTS)
    assert rts.ro_commit and rts.next_stage == mvcc.S_LOCKW
    assert all(not s.ro_commit for s in mvcc.SPECS if s.stage != mvcc.S_RTS)
