"""Bucketed static-axis padding (sweep.plan_buckets / run_grid).

Property under test: a bucket-padded run is EQUAL to the unpadded
sequential run of every config — padded co-routine slots and padded
records are inert, so padding never leaks into commit/abort/round/byte
counters (integer metrics bitwise; float latency accumulations to 1e-5,
the same fusion-order caveat as the pre-existing batched-vs-sequential
tests).

The random-grid property test uses Hypothesis when installed and falls
back to a derandomized seeded generator otherwise (the container CI image
has no hypothesis), so the property is exercised either way.
"""
import numpy as np
import pytest

from repro.core.sweep import plan_buckets, run_grid

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

KW = dict(n_nodes=2, ticks=48, warmup=8)


def _reference(protocol, workload, cfg, **kw):
    """Unpadded sequential reference: a 1-config grid with the config's
    static axes baked into the GridSpec (the legacy exact path)."""
    cfg = dict(cfg)
    kw = dict(kw)
    for ax in ("coroutines", "records_per_node", "ticks"):
        if ax in cfg:
            kw[ax] = cfg.pop(ax)
    return run_grid(protocol, workload, [cfg], **kw)[0]


def assert_padded_equals_unpadded(protocol, workload, configs, **kw):
    rows = run_grid(protocol, workload, configs, **kw)
    for cfg, row in zip(configs, rows):
        ref = _reference(protocol, workload, cfg, **kw)
        # integer/ratio metrics: masks must not leak a single count
        assert row["commits"] == ref["commits"], (cfg, row["commits"], ref["commits"])
        assert row["aborts"] == ref["aborts"], cfg
        assert row["abort_rate"] == ref["abort_rate"], cfg
        np.testing.assert_allclose(row["avg_round_trips"], ref["avg_round_trips"], rtol=1e-6)
        # float accumulations (latency, per-stage wire/queue time incl. the
        # byte terms): identical up to reduction fusion order
        np.testing.assert_allclose(row["avg_latency_us"], ref["avg_latency_us"], rtol=1e-5)
        np.testing.assert_allclose(
            row["stage_us_per_commit"], ref["stage_us_per_commit"], rtol=1e-5, atol=1e-5
        )
    return rows


def test_coroutine_padding_inert():
    rows = assert_padded_equals_unpadded(
        "occ",
        "smallbank",
        [{"hybrid": 21, "coroutines": 5}, {"hybrid": 42, "coroutines": 8}],
        coroutines=8,
        records_per_node=128,
        **KW,
    )
    assert all(r["n_buckets"] == 1 for r in rows)  # 5 and 8 share a bucket
    assert [r["coroutines"] for r in rows] == [5, 8]


def test_record_padding_inert():
    rows = assert_padded_equals_unpadded(
        "sundial",
        "ycsb",
        [
            {"hybrid": 21, "records_per_node": 48, "hot_prob": 0.6},
            {"hybrid": 42, "records_per_node": 64, "hot_prob": 0.3},
        ],
        coroutines=8,
        records_per_node=64,
        **KW,
    )
    assert all(r["n_buckets"] == 1 for r in rows)
    assert [r["records_per_node"] for r in rows] == [48, 64]


def test_ticks_padding_inert():
    """Per-config ticks in one pow2 bucket: dead ticks freeze the carry, so
    a shorter config inside a padded scan matches its exact-length run —
    including the time-derived ratios (throughput divides by the ACTIVE
    tick count)."""
    rows = assert_padded_equals_unpadded(
        "occ",
        "smallbank",
        [{"hybrid": 21, "ticks": 48}, {"hybrid": 21, "ticks": 37}, {"hybrid": 42, "ticks": 48}],
        coroutines=8,
        records_per_node=128,
        **KW,
    )
    assert all(r["n_buckets"] == 1 for r in rows)  # 37 and 48 share a pow2 bucket
    assert [r["ticks"] for r in rows] == [48, 37, 48]
    assert rows[0]["commits"] > rows[1]["commits"]  # shorter run committed less
    # throughput must be bitwise vs the exact-length reference
    ref = _reference("occ", "smallbank", {"hybrid": 21, "ticks": 37},
                     coroutines=8, records_per_node=128, **KW)
    assert np.float32(rows[1]["throughput_mtps"]) == np.float32(ref["throughput_mtps"])


def test_ticks_padding_inert_calvin():
    """CALVIN buckets ticks as epochs: padded epochs execute zero waves."""
    rows = assert_padded_equals_unpadded(
        "calvin",
        "smallbank",
        [{"ticks": 96}, {"ticks": 72}],  # both in the 128 pow2 bucket
        coroutines=8,
        records_per_node=128,
        **{**KW, "ticks": 96},
    )
    assert all(r["n_buckets"] == 1 for r in rows)
    assert rows[0]["commits"] > rows[1]["commits"]


def test_plan_buckets_ticks_axis():
    b = plan_buckets(
        [{"ticks": 48}, {"ticks": 37}, {"ticks": 96}],
        coroutines=8,
        records_per_node=64,
        ticks=48,
    )
    assert len(b) == 2
    by_t = {x.ticks: x for x in b}
    assert by_t[48].indices == (0, 1) and by_t[48].ticks_active == (48, 37)
    assert by_t[96].indices == (2,) and by_t[96].ticks_active is None
    with pytest.raises(ValueError):
        plan_buckets([{"ticks": 0}], coroutines=8, records_per_node=64, ticks=48)


def test_calvin_bucketed_padding_inert():
    rows = assert_padded_equals_unpadded(
        "calvin",
        "smallbank",
        [{"coroutines": 5}, {"coroutines": 8}],
        coroutines=8,
        records_per_node=128,
        **KW,
    )
    assert all(r["abort_rate"] == 0.0 for r in rows)


def test_multi_bucket_grid_order_and_metadata():
    """Shapes a power-of-two apart land in different buckets; output rows
    stay in the caller's config order with per-bucket metadata."""
    configs = [
        {"hybrid": 0, "coroutines": 16},
        {"hybrid": 63, "coroutines": 5},
        {"hybrid": 21, "coroutines": 6},
    ]
    rows = run_grid("nowait", "smallbank", configs, coroutines=8, records_per_node=128, **KW)
    assert [r["coroutines"] for r in rows] == [16, 5, 6]
    assert all(r["n_buckets"] == 2 for r in rows)
    assert rows[1]["bucket"] == rows[2]["bucket"] != rows[0]["bucket"]
    ref = _reference("nowait", "smallbank", configs[1], coroutines=8, records_per_node=128, **KW)
    assert rows[1]["commits"] == ref["commits"] and rows[1]["aborts"] == ref["aborts"]


# ---------------------------------------------------------------------------
# planner unit tests (pure Python)
# ---------------------------------------------------------------------------


def test_plan_buckets_grouping():
    b = plan_buckets(
        [
            {"hybrid": 1, "coroutines": 5},
            {"hybrid": 2, "coroutines": 8},
            {"hybrid": 3, "coroutines": 20},
            {"hybrid": 4},
        ],
        coroutines=8,
        records_per_node=128,
    )
    assert len(b) == 2
    by_pad = {x.coroutines: x for x in b}
    assert by_pad[8].indices == (0, 1, 3)
    assert by_pad[8].coroutines_active == (5, 8, 8)
    assert by_pad[8].records_active is None  # axis untouched -> legacy path
    assert by_pad[20].indices == (2,)
    assert by_pad[20].coroutines_active is None  # single shape, no padding
    # static axes are stripped from the knob dicts
    assert all("coroutines" not in cfg for x in b for cfg in x.knob_configs)


def test_plan_buckets_pads_to_bucket_max_not_pow2():
    (b,) = plan_buckets(
        [{"records_per_node": 33}, {"records_per_node": 48}], coroutines=8, records_per_node=64
    )
    assert b.records_per_node == 48  # max actual, not the pow2 ceiling 64
    assert b.records_active == (33, 48)


def test_plan_buckets_rejects_degenerate():
    with pytest.raises(ValueError):
        plan_buckets([{"coroutines": 0}], coroutines=8, records_per_node=64)


# ---------------------------------------------------------------------------
# the random-grid property (hypothesis when available, seeded fallback)
# ---------------------------------------------------------------------------


def _check_random_grid(seed: int):
    rng = np.random.default_rng(seed)
    n_cfg = int(rng.integers(2, 4))
    configs = []
    for _ in range(n_cfg):
        cfg = {"hybrid": int(rng.integers(0, 64)), "seed": int(rng.integers(0, 3))}
        if rng.random() < 0.8:
            cfg["coroutines"] = int(rng.integers(4, 9))  # one pow2 bucket (<=8)
        if rng.random() < 0.5:
            cfg["records_per_node"] = int(rng.integers(33, 65))  # one bucket (<=64)
        configs.append(cfg)
    assert_padded_equals_unpadded(
        "occ", "smallbank", configs, coroutines=8, records_per_node=64, **KW
    )


if HAVE_HYPOTHESIS:

    @settings(max_examples=3, deadline=None, derandomize=True)
    @given(st.integers(0, 2**31 - 1))
    @pytest.mark.slow
    def test_bucketed_equals_sequential_random_grids(seed):
        _check_random_grid(seed)

else:

    @pytest.mark.slow  # each example pays per-config sequential reference compiles
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_bucketed_equals_sequential_random_grids(seed):
        _check_random_grid(seed)
