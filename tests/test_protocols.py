"""Protocol correctness: serializability, lost updates, plane equivalence."""
import jax
import pytest

from repro.core.costmodel import ONE_SIDED, RPC, CostModel
from repro.core.engine import EngineConfig, run
from repro.core.protocols import PROTOCOLS
from repro.core.protocols import calvin as calvin_mod
from repro.core.validate import check_no_lost_updates, extract_history, is_serializable
from repro.workloads import make_workload

SLOT_PROTOS = ("nowait", "waitdie", "occ", "mvcc", "sundial")


def _run(proto_name, prim, workload="ycsb", ticks=160, hot_prob=0.5, coroutines=12):
    ec = EngineConfig(
        protocol=proto_name,
        n_nodes=4,
        coroutines=coroutines,
        records_per_node=64,  # small store => real contention
        max_ops=4,
        rw=2,
        hybrid=(prim,) * 6,
        history_cap=8192,
    )
    cm = CostModel()
    if workload == "ycsb":
        wl = make_workload("ycsb", ec.n_records, hot_prob=hot_prob)
        wl = wl._replace(max_ops=4, gen=_truncate_gen(wl.gen, 4))
    else:
        wl = make_workload(workload, ec.n_records)
    ec = EngineConfig(**{**ec.__dict__, "rw": wl.rw, "max_ops": wl.max_ops})
    proto = PROTOCOLS[proto_name]
    st, store, m = jax.jit(lambda: run(proto.tick, ec, cm, wl, ticks))()
    return st, store, m


def _truncate_gen(gen, k):
    def g(key, node, slot):
        keys, is_w, valid = gen(key, node, slot)
        return keys[:k], is_w[:k], valid[:k]

    return g


@pytest.mark.slow  # ~2 min across the 10 param combos; nightly CI runs it
@pytest.mark.parametrize("proto", SLOT_PROTOS)
@pytest.mark.parametrize("prim", [RPC, ONE_SIDED])
def test_serializable_under_contention(proto, prim):
    st, store, m = _run(proto, prim)
    # 2PL protocols legitimately starve under this pathological hot-spot
    # (the paper's TPC-C shows >50% aborts); the property under test is
    # serializability, not throughput.
    floor = 20 if proto in ("nowait", "waitdie") else 50
    assert int(m["commits"]) + int(m["aborts"]) > floor, m
    hist = extract_history(st)
    ok, cycle = is_serializable(hist)
    assert ok, f"{proto} produced a non-serializable history: cycle={cycle}"
    ok, why = check_no_lost_updates(hist, store)
    assert ok, f"{proto}: {why}"


@pytest.mark.parametrize("proto", SLOT_PROTOS)
def test_hybrid_codes_serializable(proto):
    # a genuinely mixed code: fetch/lock one-sided, validate/log rpc, ...
    code = (ONE_SIDED, RPC, ONE_SIDED, RPC, ONE_SIDED, RPC)
    ec = EngineConfig(
        protocol=proto, n_nodes=4, coroutines=10, records_per_node=64,
        rw=2, max_ops=2, hybrid=code, history_cap=4096,
    )
    wl = make_workload("smallbank", ec.n_records)
    st, store, m = jax.jit(lambda: run(PROTOCOLS[proto].tick, ec, CostModel(), wl, 160))()
    assert int(m["commits"]) > 50
    ok, cycle = is_serializable(extract_history(st))
    assert ok, cycle


def test_waitdie_waits_more_aborts_less():
    _, _, m_nw = _run("nowait", ONE_SIDED, hot_prob=0.8)
    _, _, m_wd = _run("waitdie", ONE_SIDED, hot_prob=0.8)
    assert float(m_wd["abort_rate"]) <= float(m_nw["abort_rate"]) + 0.02


def test_mvcc_readonly_fast_path():
    """MVCC read-only txns commit without lock/log/commit rounds."""
    _, _, m_mvcc = _run("mvcc", ONE_SIDED, workload="smallbank")
    _, _, m_occ = _run("occ", ONE_SIDED, workload="smallbank")
    assert float(m_mvcc["avg_round_trips"]) < float(m_occ["avg_round_trips"])


def test_calvin_deterministic_and_conservative():
    ec = EngineConfig(
        protocol="calvin", n_nodes=4, coroutines=8, records_per_node=64,
        rw=2, max_ops=2, hybrid=(RPC,) * 6,
    )
    wl = make_workload("smallbank", ec.n_records)
    cm = CostModel()
    s1, m1 = jax.jit(lambda: calvin_mod.run_epochs(ec, cm, wl, 20))()
    s2, m2 = jax.jit(lambda: calvin_mod.run_epochs(ec, cm, wl, 20))()
    # deterministic: same inputs -> byte-identical final store
    assert bool((s1["data"] == s2["data"]).all())
    assert float(m1["abort_rate"]) == 0.0
    assert int(m1["commits"]) == 20 * ec.n_slots


def test_mvcc_more_slots_fewer_read_aborts():
    """Paper §4.4: slot count trades memory vs overflow read-aborts."""
    rates = {}
    for slots in (2, 8):
        ec = EngineConfig(
            protocol="mvcc", n_nodes=4, coroutines=24, records_per_node=64,
            rw=2, max_ops=4, hybrid=(ONE_SIDED,) * 6, mvcc_slots=slots,
        )
        wl = make_workload("ycsb", ec.n_records, hot_prob=0.7)
        wl = wl._replace(max_ops=4, gen=_truncate_gen(wl.gen, 4))
        ec = EngineConfig(**{**ec.__dict__, "rw": wl.rw, "max_ops": wl.max_ops})
        _, _, m = jax.jit(
            lambda ec=ec, wl=wl: run(PROTOCOLS["mvcc"].tick, ec, CostModel(), wl, 200)
        )()
        rates[slots] = float(m["abort_rate"])
    assert rates[8] <= rates[2] + 0.01, rates


def test_one_sided_lower_latency_low_load():
    _, _, m_rpc = _run("nowait", RPC, workload="smallbank", coroutines=4)
    _, _, m_os = _run("nowait", ONE_SIDED, workload="smallbank", coroutines=4)
    assert float(m_os["avg_latency_us"]) < float(m_rpc["avg_latency_us"])
