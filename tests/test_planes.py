"""SPMD communication-plane tests (repro.core.planes).

Runs the shard_map planes in a subprocess with 4 forced host devices (the
main test process must keep seeing 1 device) and pins os_read / os_cas /
rpc_call results against dense single-device engine semantics:

  * os_read(data, keys)   == data[keys]                  (raw DMA gather)
  * os_cas                == arbitrated first-wins CAS (one winner per free
                             word, as engine.try_lock's arbitration)
  * rpc_call              == handler applied at the owner against the full
                             request set (replies see pre-mutation state)

Also covers the routing fabric's finite-cap path: requests beyond the
per-destination buffer are DROPPED — zero replies / not-won, never another
request's payload (the aliasing bug fixed in _route)."""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.planes import make_planes

n_nodes, rpn, rw = 4, 8, 2
R = n_nodes * rpn
mesh = Mesh(np.asarray(jax.devices()).reshape(n_nodes), ("node",))
os_read, os_cas, rpc_call = make_planes(mesh, "node", rpn, rw)

rng = np.random.default_rng(0)
data = jnp.asarray(rng.integers(0, 1000, (R, rw)), jnp.int32)
# keys: per-node blocks of 3 local + 5 remote, including duplicates
keys = jnp.asarray(rng.integers(0, R, (n_nodes * 8,)), jnp.int32)

# --- os_read == dense gather -------------------------------------------
vals = jax.jit(os_read)(data, keys)
assert (np.asarray(vals) == np.asarray(data)[np.asarray(keys)]).all(), "os_read != data[keys]"

# --- os_cas: one winner per free lock word -----------------------------
locks = jnp.zeros((R,), jnp.int32).at[5].set(99)  # key 5 pre-held
cas_keys = jnp.asarray([5, 5, 9, 9, 9, 12, 3, 3] * n_nodes, jnp.int32)
new = jnp.arange(1, cas_keys.shape[0] + 1, dtype=jnp.int32)
locks2, won = jax.jit(os_cas)(locks, cas_keys, new)
won = np.asarray(won); ck = np.asarray(cas_keys)
assert won[ck == 5].sum() == 0, "CAS won a held lock"
for k in (9, 12, 3):
    assert won[ck == k].sum() == 1, (k, won)
locks2 = np.asarray(locks2)
assert locks2[5] == 99
for k in (9, 12, 3):
    assert locks2[k] == int(np.asarray(new)[won & (ck == k)][0])

# --- rpc_call: owner-side handler == dense reference --------------------
def handler(data_l, addrs, valid):
    # read-then-increment: replies see pre-mutation state
    replies = jnp.where(valid[:, None], data_l[jnp.clip(addrs, 0, data_l.shape[0] - 1)], 0)
    data_l = data_l.at[jnp.where(valid, addrs, data_l.shape[0])].add(1, mode="drop")
    return data_l, replies

data2, replies = jax.jit(lambda d, k: rpc_call(d, k, handler))(data, keys)
np_data, np_keys = np.asarray(data), np.asarray(keys)
assert (np.asarray(replies) == np_data[np_keys]).all(), "rpc replies != pre-state gather"
exp = np_data.copy()
np.add.at(exp, np_keys, 1)
assert (np.asarray(data2) == exp).all(), "rpc handler mutation != dense scatter-add"

# --- finite cap: dropped requests are dropped, not aliased --------------
cap = 2
os_read_c, os_cas_c, rpc_call_c = make_planes(mesh, "node", rpn, rw, cap=cap)
# every request from every node targets node 0: per shard, slots 0..7 but cap=2
hot = jnp.asarray([0, 1, 2, 3, 4, 5, 6, 7] * n_nodes, jnp.int32)
vals_c = np.asarray(jax.jit(os_read_c)(data, hot))
kept = np.tile(np.arange(8) < cap, n_nodes)  # slot < cap, per source shard
exp = np.where(kept[:, None], np_data[np.asarray(hot)], 0)
assert (vals_c == exp).all(), (vals_c, exp)

locks0 = jnp.zeros((R,), jnp.int32)
_, won_c = jax.jit(os_cas_c)(locks0, hot, jnp.arange(1, 33, dtype=jnp.int32))
won_c = np.asarray(won_c)
assert not won_c[~kept].any(), "dropped CAS reported as won"
# kept requests: distinct keys 0,1 per shard -> one winner each
for k in (0, 1):
    assert won_c[kept & (np.asarray(hot) == k)].sum() == 1

_, rep_c = jax.jit(lambda d, k: rpc_call_c(d, k, handler))(data, hot)
rep_c = np.asarray(rep_c)
assert (rep_c[~kept] == 0).all(), "dropped RPC got a non-zero (aliased) reply"
assert (rep_c[kept] == np_data[np.asarray(hot)[kept]]).all()
print("PLANES SPMD OK")
"""


def test_planes_spmd_vs_dense():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _CODE], capture_output=True, text=True, env=env, timeout=300
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PLANES SPMD OK" in out.stdout
