"""Per-architecture smoke tests (reduced same-family configs, CPU):
one forward + one train step (shapes + no NaNs), prefill/decode parity."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, reduced_config
from repro.models.decode import lm_decode_step, lm_prefill
from repro.models.lm import init_lm, lm_apply
from repro.sharding import AxisRules, unzip_params
from repro.train.steps import build_train_step

B, S = 2, 32
SHD = AxisRules(None)


def _batch(cfg, key=jax.random.PRNGKey(0)):
    b = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.encoder_decoder:
        b["frames"] = jax.random.normal(key, (B, cfg.enc_seq_len, cfg.d_model), jnp.float32)
    if cfg.mrope_sections is not None:
        b["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (B, 3, S)
        ).astype(jnp.int32)
    return b


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(arch_id):
        if arch_id not in cache:
            cfg = reduced_config(arch_id)
            params = unzip_params(init_lm(jax.random.PRNGKey(1), cfg, jnp.float32))[0]
            cache[arch_id] = (cfg, params)
        return cache[arch_id]

    return get


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_and_train_step(arch_id, arch_state):
    cfg, params = arch_state(arch_id)
    batch = _batch(cfg)
    logits = jax.jit(lambda p, b: lm_apply(p, cfg, SHD, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"

    train_step, opt = build_train_step(cfg, SHD, "adamw")
    p2, o2, metrics = jax.jit(train_step)(params, opt.init(params), jnp.int32(0), batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b, jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), params, p2)
    )
    assert delta > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_prefill_decode_match_forward(arch_id, arch_state):
    cfg, params = arch_state(arch_id)
    batch = _batch(cfg)
    Tp = 4
    fb = {"tokens": batch["tokens"][:, : Tp + 1]}
    if "frames" in batch:
        fb["frames"] = batch["frames"]
    if "positions" in batch:
        fb["positions"] = batch["positions"][:, :, : Tp + 1]
    full = lm_apply(params, cfg, SHD, fb)
    pb = {k: (v[:, :Tp] if k == "tokens" else v[:, :, :Tp] if k == "positions" else v) for k, v in fb.items()}
    lg_p, cache = lm_prefill(params, cfg, SHD, pb, pad_to=Tp + 4)
    assert float(jnp.abs(lg_p - full[:, Tp - 1]).max()) < 2e-2
    db = {"token": fb["tokens"][:, Tp]}
    if cfg.mrope_sections is not None:
        db["positions"] = jnp.full((B, 3), Tp, jnp.int32)
    lg_d, cache2 = lm_decode_step(params, cfg, SHD, cache, db)
    assert float(jnp.abs(lg_d - full[:, Tp]).max()) < 2e-2
    assert int(cache2["len"]) == Tp + 1
