"""LM serving with RCC-transactional KV-page admission (integration demo).

This is the DESIGN.md §Arch-applicability integration point: the paper's
distributed KV store manages the serving engine's KV-cache page table.
Concurrent admission requests race for pages through the NOWAIT protocol:
conflicting allocations abort-and-retry; throughput/abort metrics come from
the same engine that runs the paper's benchmarks.

  PYTHONPATH=src python examples/txn_serving.py
"""
import jax
import jax.numpy as jnp

from repro.core.costmodel import ONE_SIDED, CostModel
from repro.core.engine import EngineConfig, Workload, run
from repro.core.registry import get_protocol

# page table: 4 nodes x 512 pages; an admission txn grabs 4 pages
N_NODES, PAGES_PER_NODE, PAGES_PER_REQ = 4, 512, 4


def make_admission_workload(n_pages: int) -> Workload:
    def gen(key, node, slot):
        # preferred pages cluster near the requester's node (locality), which
        # creates realistic allocation contention between co-located slots
        k1, k2 = jax.random.split(key)
        base = node * PAGES_PER_NODE
        local = jax.random.randint(k1, (PAGES_PER_REQ,), 0, PAGES_PER_NODE // 4)
        keys = (base + local) % n_pages

        def dedup(i, r, ks):
            clash = (ks[:i] == ks[i]).any()
            return ks.at[i].set(jnp.where(clash, (ks[i] + i * 7 + r + 1) % n_pages, ks[i]))

        for r in range(4):
            for i in range(1, PAGES_PER_REQ):
                keys = dedup(i, r, keys)
        valid = jnp.ones((PAGES_PER_REQ,), bool)
        return keys.astype(jnp.int32), valid, valid  # all writes (allocations)

    def execute(keys, is_w, valid, rvals):
        return rvals.at[:, 0].add(1)  # bump page generation counter

    return Workload(
        name="kv_admission", rw=1, max_ops=PAGES_PER_REQ, init_value=0,
        gen=gen, execute=execute, exec_ticks=1,
    )


def main():
    ec = EngineConfig(
        protocol="nowait", n_nodes=N_NODES, coroutines=24,
        records_per_node=PAGES_PER_NODE, rw=1, max_ops=PAGES_PER_REQ,
        hybrid=(ONE_SIDED,) * 6,
    )
    wl = make_admission_workload(ec.n_records)
    _, store, m = jax.jit(lambda: run(get_protocol("nowait").tick, ec, CostModel(), wl, 300, warmup=50))()
    print(
        f"[admission] {int(m['commits'])} admissions, abort_rate={float(m['abort_rate']):.3f}, "
        f"p50-ish latency={float(m['avg_latency_us']):.1f}us"
    )
    print(f"[admission] page generations bumped: {int(store['data'].sum())}")

    # then serve a model against the admitted pages (reduced config decode)
    print("[serve] running batched prefill+decode with the admitted budget...")
    import repro.launch.serve as serve
    import sys

    sys.argv = ["serve", "--arch", "stablelm-1.6b", "--batch", "2", "--prompt-len", "16", "--gen-len", "8"]
    serve.main()


if __name__ == "__main__":
    main()
