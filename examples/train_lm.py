"""End-to-end LM training driver with fault tolerance.

Default: a small same-family model for a quick CPU run.  --full trains a
~100M-param stablelm-family model for a few hundred steps (long on 1 CPU
core; sized for a real accelerator).

  PYTHONPATH=src python examples/train_lm.py --steps 120
  PYTHONPATH=src python examples/train_lm.py --full --steps 300
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.data.pipeline import make_pipeline
from repro.ft.runner import TrainRunner
from repro.models.lm import init_lm
from repro.sharding import AxisRules, unzip_params
from repro.train.steps import build_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--full", action="store_true", help="~100M params")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()

    if args.full:
        base, _ = get_config("stablelm-1.6b")
        cfg = dataclasses.replace(
            base, n_layers=8, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
            d_ff=2048, vocab_size=32000, microbatch=1, remat="none",
        )
        batch, seq = 8, 512
    else:
        cfg = reduced_config("stablelm-1.6b")
        batch, seq = 8, 128
    shd = AxisRules(None)
    print(f"[example] training {cfg.name}-family model: {cfg.param_count():,} params")

    train_step, optimizer = build_train_step(cfg, shd)
    jitted = jax.jit(train_step, donate_argnums=(0, 1))

    def init_state():
        params = unzip_params(init_lm(jax.random.PRNGKey(0), cfg, jnp.float32))[0]
        return params, optimizer.init(params)

    init_data, next_batch = make_pipeline(cfg.vocab_size, batch, seq, copy_frac=0.5)
    runner = TrainRunner(
        jitted, init_state, next_batch, init_data,
        ckpt_dir=args.ckpt, ckpt_every=50, fail_at=args.fail_at,
    )
    out = runner.run(args.steps)
    losses = out["losses"]
    print(f"[example] loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
    assert losses[-1] < losses[0]
    print("[example] ok — model learned the synthetic copy/zipf structure")


if __name__ == "__main__":
    main()
