"""Exhaustive hybrid-protocol search (paper §5's expert/common interface).

Enumerates all 2^6 stage-primitive codings for a protocol x workload and
prints the ranking — "solid evidence of the best hybrid design instead of
guess and try" (paper).  Common users: run with defaults.  Expert users:
pass --code to evaluate one specific design.

  PYTHONPATH=src python examples/hybrid_search.py --protocol sundial --workload smallbank --top 8
"""
import argparse

from repro.core.costmodel import N_HYBRID_STAGES, STAGE_NAMES

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import run_cell  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--protocol", default="sundial")
    ap.add_argument("--workload", default="smallbank")
    ap.add_argument("--code", default=None, help="e.g. 010110 (1 = one-sided per stage)")
    ap.add_argument("--top", type=int, default=8)
    ap.add_argument("--ticks", type=int, default=160)
    args = ap.parse_args()

    stages = ",".join(STAGE_NAMES[:N_HYBRID_STAGES])
    if args.code:
        code = tuple(int(c) for c in args.code)
        m, _, _ = run_cell(args.protocol, args.workload, code, ticks=args.ticks)
        print(f"code={args.code} ({stages})")
        print(f"  throughput={m['throughput_mtps']*1e3:.1f} Ktps latency={m['avg_latency_us']:.2f}us "
              f"aborts={m['abort_rate']:.3f}")
        return

    results = []
    for ci in range(2 ** N_HYBRID_STAGES):
        code = tuple((ci >> i) & 1 for i in range(N_HYBRID_STAGES))
        m, _, _ = run_cell(args.protocol, args.workload, code, ticks=args.ticks, coroutines=40)
        results.append((m["throughput_mtps"], m["avg_latency_us"], m["hybrid"]))
        print(f"\r  searched {ci+1}/64", end="", flush=True)
    print()
    results.sort(reverse=True)
    print(f"top {args.top} hybrid designs for {args.protocol} on {args.workload} (stages: {stages}):")
    for thr, lat, code in results[: args.top]:
        print(f"  code={code}  {thr*1e3:8.1f} Ktps  {lat:6.2f} us")
    print(f"worst: code={results[-1][2]}  {results[-1][0]*1e3:.1f} Ktps")


if __name__ == "__main__":
    main()
