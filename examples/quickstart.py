"""Quickstart: the RCC engine + the LM stack in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.costmodel import ONE_SIDED, RPC, CostModel
from repro.core.engine import EngineConfig, run
from repro.core.protocols import PROTOCOLS
from repro.core.protocols import calvin as calvin_mod
from repro.workloads import make_workload

# ---------------------------------------------------------------------------
# 1. Six concurrency-control protocols, one engine, one workload
# ---------------------------------------------------------------------------
print("=== SmallBank, 4 nodes x 16 co-routines, one-sided vs RPC ===")
print(f"{'protocol':9s} {'impl':10s} {'Ktps':>8s} {'lat us':>8s} {'abort%':>7s} {'RTs':>5s}")
cm = CostModel()
for proto in ("nowait", "waitdie", "occ", "mvcc", "sundial"):
    for impl, prim in (("rpc", RPC), ("one-sided", ONE_SIDED)):
        ec = EngineConfig(
            protocol=proto, n_nodes=4, coroutines=16, records_per_node=1024,
            rw=2, max_ops=2, hybrid=(prim,) * 6,
        )
        wl = make_workload("smallbank", ec.n_records)
        _, _, m = jax.jit(lambda ec=ec, wl=wl, p=proto: run(PROTOCOLS[p].tick, ec, cm, wl, 300, warmup=60))()
        print(
            f"{proto:9s} {impl:10s} {float(m['throughput_mtps'])*1e3:8.1f} "
            f"{float(m['avg_latency_us']):8.2f} {float(m['abort_rate'])*100:6.2f}% "
            f"{float(m['avg_round_trips']):5.2f}"
        )

ec = EngineConfig(protocol="calvin", n_nodes=4, coroutines=16, records_per_node=1024, rw=2, max_ops=2)
wl = make_workload("smallbank", ec.n_records)
_, m = jax.jit(lambda: calvin_mod.run_epochs(ec, cm, wl, 40))()
print(f"{'calvin':9s} {'epoch':10s} {float(m['throughput_mtps'])*1e3:8.1f} "
      f"{float(m['avg_latency_us']):8.2f}   0.00% {float(m['avg_round_trips']):5.2f}")

# ---------------------------------------------------------------------------
# 2. A hybrid protocol: cherry-pick the faster primitive per stage (paper §5)
# ---------------------------------------------------------------------------
print("\n=== hybrid MVCC (fetch/validate via RPC, lock/log/commit one-sided) ===")
code = (RPC, ONE_SIDED, RPC, ONE_SIDED, ONE_SIDED, ONE_SIDED)
ec = EngineConfig(protocol="mvcc", n_nodes=4, coroutines=16, records_per_node=1024,
                  rw=2, max_ops=2, hybrid=code)
wl = make_workload("smallbank", ec.n_records)
_, _, m = jax.jit(lambda: run(PROTOCOLS["mvcc"].tick, ec, cm, wl, 300, warmup=60))()
print(f"hybrid code={''.join(map(str, code))}  ->  {float(m['throughput_mtps'])*1e3:.1f} Ktps, "
      f"{float(m['avg_latency_us']):.2f} us")

# ---------------------------------------------------------------------------
# 3. The LM substrate: one forward + one train step of a reduced arch
# ---------------------------------------------------------------------------
print("\n=== LM substrate (reduced qwen2.5-32b family config) ===")
from repro.configs import reduced_config
from repro.models.lm import init_lm, lm_apply
from repro.sharding import AxisRules, unzip_params
from repro.train.steps import build_train_step

cfg = reduced_config("qwen2.5-32b")
shd = AxisRules(None)
params = unzip_params(init_lm(jax.random.PRNGKey(0), cfg, jnp.float32))[0]
batch = {
    "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size),
    "labels": jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size),
}
logits = jax.jit(lambda p, b: lm_apply(p, cfg, shd, b))(params, batch)
step, opt = build_train_step(cfg, shd)
p2, o2, metrics = jax.jit(step)(params, opt.init(params), jnp.int32(0), batch)
print(f"params={cfg.param_count():,}  logits={logits.shape}  loss={float(metrics['loss']):.3f}")
print("quickstart ok")
