"""Quickstart: the RCC engine through the repro.api front door + the LM stack.

  PYTHONPATH=src python examples/quickstart.py

One ExperimentSpec describes a whole sweep; plan() shows what will compile
on which mesh; execute() returns one metrics row per config.  Protocols
come from the plugin registry (repro.core.registry) — all six built-ins,
plus anything you register yourself.
"""
import jax
import jax.numpy as jnp

from repro.api import ExperimentSpec, execute, plan
from repro.core.costmodel import ONE_SIDED, RPC
from repro.core.registry import protocol_names

# ---------------------------------------------------------------------------
# 1. Six concurrency-control protocols, one front door, one workload
# ---------------------------------------------------------------------------
print("=== SmallBank, 4 nodes x 16 co-routines, one-sided vs RPC ===")
print(f"{'protocol':9s} {'impl':10s} {'Ktps':>8s} {'lat us':>8s} {'abort%':>7s} {'RTs':>5s}")
KW = dict(n_nodes=4, coroutines=16, records_per_node=1024, ticks=300, warmup=60)
for proto in protocol_names():
    # the rpc/one-sided pair runs as ONE compiled 2-config grid per protocol
    spec = ExperimentSpec(
        protocol=proto,
        workload="smallbank",
        configs=({"hybrid": (RPC,) * 6}, {"hybrid": (ONE_SIDED,) * 6}),
        **KW,
    )
    for impl, m in zip(("rpc", "one-sided"), execute(plan(spec)).rows):
        print(
            f"{proto:9s} {impl:10s} {float(m['throughput_mtps'])*1e3:8.1f} "
            f"{float(m['avg_latency_us']):8.2f} {float(m['abort_rate'])*100:6.2f}% "
            f"{float(m['avg_round_trips']):5.2f}"
        )

# ---------------------------------------------------------------------------
# 2. A hybrid protocol: cherry-pick the faster primitive per stage (paper §5)
#    — and show the planner's summary of what actually runs
# ---------------------------------------------------------------------------
print("\n=== hybrid MVCC (fetch/validate via RPC, lock/log/commit one-sided) ===")
code = (RPC, ONE_SIDED, RPC, ONE_SIDED, ONE_SIDED, ONE_SIDED)
pl = plan(ExperimentSpec(protocol="mvcc", workload="smallbank", configs=({"hybrid": code},), **KW))
print(pl.summary())
m = execute(pl).row
print(f"hybrid code={''.join(map(str, code))}  ->  {float(m['throughput_mtps'])*1e3:.1f} Ktps, "
      f"{float(m['avg_latency_us']):.2f} us")

# ---------------------------------------------------------------------------
# 3. The LM substrate: one forward + one train step of a reduced arch
# ---------------------------------------------------------------------------
print("\n=== LM substrate (reduced qwen2.5-32b family config) ===")
from repro.configs import reduced_config
from repro.models.lm import init_lm, lm_apply
from repro.sharding import AxisRules, unzip_params
from repro.train.steps import build_train_step

cfg = reduced_config("qwen2.5-32b")
shd = AxisRules(None)
params = unzip_params(init_lm(jax.random.PRNGKey(0), cfg, jnp.float32))[0]
batch = {
    "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size),
    "labels": jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size),
}
logits = jax.jit(lambda p, b: lm_apply(p, cfg, shd, b))(params, batch)
step, opt = build_train_step(cfg, shd)
p2, o2, metrics = jax.jit(step)(params, opt.init(params), jnp.int32(0), batch)
print(f"params={cfg.param_count():,}  logits={logits.shape}  loss={float(metrics['loss']):.3f}")
print("quickstart ok")
