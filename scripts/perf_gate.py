"""CI perf-regression gate (bench-smoke job), driven through ``repro.api``.

Guards the planner/executor's load-bearing properties:

  1. single-compile: the paper's exhaustive 2^6 hybrid enumeration must run
     as ONE vmapped program.  ``plan()`` accounts for it
     (``ExecutionPlan.expected_compiles == 1``) and the measured jit-cache
     delta must match.  A protocol accidentally Python-branching on a
     traced knob silently falls back to 64 compilations — this gate
     catches it.
  2. bucketed static axes: a co-routine sweep whose points share one shape
     bucket must compile exactly ``expected_compiles`` (== n_buckets == 1)
     more programs, not one per config.  A regression in the bucketing
     planner or in the active-extent knob plumbing (EngineConfig.active_*)
     shows up as one compile per distinct static shape.
  3. node-sharded tick: the node-sharded engine must compile ONE SPMD
     program per mesh shape — every knob stays traced, so a family of
     configs on a fixed mesh shares the compiled sharded tick.
  4. wall-clock budgets: each sweep must finish inside its ``--budget``
     seconds end-to-end (compile + run).  The budgets are generous for
     slow CI runners; a per-cell-compile regression blows them by an
     order of magnitude.
  5. kernel plane (DESIGN.md §9): a roofline-style ticks/sec gate.  The
     same sweep runs on the jnp plane and on the Pallas plane (interpret
     mode when no accelerator is attached), warm-cache timed.  The jnp
     plane must clear ``--min-ticks-per-sec`` and the kernel plane must
     stay within ``--kernel-slowdown``x of it — interpret-mode emulation
     is slow, but a constant-factor regression (e.g. the dispatch layer
     re-tracing per tick) blows even that generous ratio.  Counter parity
     between the planes is re-checked here so the perf numbers are known
     to come from equivalent programs.

With ``--bench-out PATH`` the measured numbers are written as a
machine-readable ``BENCH_<rev>.json`` for the bench-smoke artifact trail.

Run from a fresh interpreter (the compile-cache assertions count programs
compiled in THIS process).
"""
import argparse
import json
import os
import subprocess
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks.common import add_device_args, configure_devices  # jax-free


def _measured_delta(before: dict, after: dict, cache: str):
    if before[cache] < 0 or after[cache] < 0:
        return None  # no introspection in this JAX version
    return after[cache] - before[cache]


def gate_hybrid_enumeration(budget_s: float) -> None:
    from repro import api

    spec = api.ExperimentSpec(
        protocol="sundial",
        workload="smallbank",
        configs=[{"hybrid": c} for c in api.all_hybrid_codes()],
        n_nodes=2, coroutines=12, records_per_node=4096, ticks=96, warmup=8,
    )
    pl = api.plan(spec)
    print(pl.summary())
    assert pl.expected_compiles == 1, (
        f"planner budgeted {pl.expected_compiles} compiles for the 2^6 enumeration (want 1)"
    )
    before = api.compile_stats()
    t0 = time.time()
    rows = api.execute(pl).rows
    wall = time.time() - t0
    assert len(rows) == 64 and all(r["commits"] > 0 for r in rows), "sweep produced bad rows"
    delta = _measured_delta(before, api.compile_stats(), pl.cache)
    if delta is not None:
        assert delta == pl.expected_compiles, (
            f"2^6 hybrid enumeration compiled {delta} programs "
            f"(planner budgeted {pl.expected_compiles}): a static/traced knob split regression"
        )
    assert wall < budget_s, f"hybrid enumeration took {wall:.1f}s (budget {budget_s:.0f}s)"
    compiles = (
        f"{delta} compile(s)" if delta is not None else "compile count UNCHECKED (no introspection)"
    )
    print(f"perf gate ok: 64-coding sweep = {compiles}, {wall:.1f}s < {budget_s:.0f}s budget")
    return {"wall_s": round(wall, 3), "compiles": delta, "budget_s": budget_s}


def gate_bucketed_coroutines(budget_s: float) -> None:
    """A 4-point co-routine sweep inside one power-of-two shape bucket must
    cost exactly one compilation (== expected_compiles), not one per config."""
    from repro import api

    spec = api.ExperimentSpec(
        protocol="sundial",
        workload="smallbank",
        configs=[{"hybrid": 0b010101, "coroutines": c} for c in (10, 12, 14, 16)],
        n_nodes=2, coroutines=12, records_per_node=4096, ticks=96, warmup=8,
    )
    pl = api.plan(spec)
    print(pl.summary())
    assert pl.expected_compiles == 1, (
        f"4-point co-routine sweep planned {pl.expected_compiles} bucket(s)/compile(s) (want 1)"
    )
    before = api.compile_stats()
    t0 = time.time()
    rows = api.execute(pl).rows
    wall = time.time() - t0
    assert all(r["commits"] > 0 for r in rows), "bucketed sweep produced bad rows"
    assert [r["coroutines"] for r in rows] == [10, 12, 14, 16]
    assert rows[0]["n_buckets"] == 1
    delta = _measured_delta(before, api.compile_stats(), pl.cache)
    if delta is not None:
        assert delta == pl.expected_compiles, (
            f"bucketed co-routine sweep compiled {delta} programs "
            f"(planner budgeted {pl.expected_compiles} for {len(spec.configs)} configs): "
            "the bucketing planner or active-extent knobs regressed"
        )
        compiles = f"{delta} compile(s)"
    else:
        compiles = "compile count UNCHECKED (no introspection)"
    assert wall < budget_s, f"bucketed co-routine sweep took {wall:.1f}s (budget {budget_s:.0f}s)"
    print(
        f"perf gate ok: 4-point co-routine sweep = 1 bucket, "
        f"{compiles}, {wall:.1f}s < {budget_s:.0f}s budget"
    )
    return {"wall_s": round(wall, 3), "compiles": delta, "budget_s": budget_s}


def gate_node_sharded_tick(budget_s: float) -> None:
    """The node-sharded engine must compile ONE SPMD program per mesh shape:
    every knob (hybrid coding, seed) stays traced through the api 'node'
    layout, so a family of configs on a fixed mesh shares the compiled
    sharded tick.  Runs on however many devices the process sees (1 in
    bench-smoke; the spmd-test job exercises the same contract on a
    4-fake-host mesh)."""
    from repro import api

    kw = dict(n_nodes=2, coroutines=12, records_per_node=4096, ticks=96, warmup=8)
    plans = [
        api.plan(
            api.ExperimentSpec(
                protocol="sundial", workload="smallbank", configs=(cfg,),
                node_shards=1, layout="node", **kw,
            )
        )
        for cfg in ({"hybrid": 0b010101}, {"hybrid": 0b101010}, {"seed": 7})
    ]
    assert all(pl.expected_compiles == 1 for pl in plans)
    before = api.compile_stats()
    t0 = time.time()
    rows = [api.execute(pl).row for pl in plans]
    wall = time.time() - t0
    assert all(r["commits"] > 0 for r in rows), "node-sharded cells produced bad rows"
    delta = _measured_delta(before, api.compile_stats(), "node")
    if delta is not None:
        # expected_compiles is a cold-cache bound per plan; the three plans
        # share one (GridSpec, mesh) program, so the measured total is 1
        assert delta == 1, (
            f"node-sharded tick compiled {delta} programs for 3 configs on one mesh "
            "(want 1): a knob leaked into the compiled program structure"
        )
        compiles = f"{delta} compile(s)"
    else:
        compiles = "compile count UNCHECKED (no introspection)"
    assert wall < budget_s, f"node-sharded cells took {wall:.1f}s (budget {budget_s:.0f}s)"
    print(f"perf gate ok: 3 node-sharded configs = {compiles}, {wall:.1f}s < {budget_s:.0f}s budget")
    return {"wall_s": round(wall, 3), "compiles": delta, "budget_s": budget_s}


_PARITY_COUNTERS = ("commits", "aborts", "abort_rate", "throughput_mtps", "avg_round_trips")


def gate_kernel_plane(budget_s: float, slowdown: float, min_tps: float) -> dict:
    """Roofline-style ticks/sec gate for the kernel plane (DESIGN.md §9)."""
    import numpy as np

    from repro import api
    from repro.kernels import ops

    kernel_plane = ops.PALLAS if ops.default_plane() == ops.PALLAS else ops.PALLAS_INTERPRET
    kw = dict(n_nodes=2, coroutines=12, records_per_node=1024, ticks=96, warmup=8)
    configs = tuple({"hybrid": c} for c in (0, 21, 42, 63))
    t0 = time.time()
    result = {"kernel_plane": kernel_plane, "protocols": {}}
    for proto in ("mvcc", "sundial"):
        timed, rows = {}, {}
        for plane in (ops.JNP, kernel_plane):
            pl = api.plan(
                api.ExperimentSpec(
                    protocol=proto, workload="smallbank", configs=configs,
                    kernel_plane=plane, **kw,
                )
            )
            rows[plane] = api.execute(pl).rows  # cold: compile + run
            t1 = time.time()
            api.execute(pl)  # warm-cache timed pass
            wall = time.time() - t1
            timed[plane] = kw["ticks"] * len(configs) / max(wall, 1e-9)
        for a, b in zip(rows[ops.JNP], rows[kernel_plane]):
            for k in _PARITY_COUNTERS:
                assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), (
                    f"{proto}: kernel plane {kernel_plane!r} diverged from jnp on {k!r} — "
                    "the ticks/sec numbers below would compare inequivalent programs"
                )
        jnp_tps, ker_tps = timed[ops.JNP], timed[kernel_plane]
        assert jnp_tps >= min_tps, (
            f"{proto}: jnp plane ran {jnp_tps:.1f} ticks/s (floor {min_tps:.0f})"
        )
        assert ker_tps >= jnp_tps / slowdown, (
            f"{proto}: {kernel_plane} plane ran {ker_tps:.1f} ticks/s vs jnp {jnp_tps:.1f} — "
            f"worse than the {slowdown:.0f}x roofline ratio"
        )
        result["protocols"][proto] = {
            "jnp_ticks_per_s": round(jnp_tps, 2),
            "kernel_ticks_per_s": round(ker_tps, 2),
            "slowdown_x": round(jnp_tps / max(ker_tps, 1e-9), 2),
        }
        print(
            f"perf gate ok: {proto} kernel plane {kernel_plane} = {ker_tps:.1f} ticks/s "
            f"(jnp {jnp_tps:.1f}, ratio {jnp_tps / max(ker_tps, 1e-9):.1f}x <= {slowdown:.0f}x)"
        )
    wall = time.time() - t0
    assert wall < budget_s, f"kernel plane gate took {wall:.1f}s (budget {budget_s:.0f}s)"
    result.update(wall_s=round(wall, 3), budget_s=budget_s)
    return result


def _rev() -> str:
    rev = os.environ.get("GITHUB_SHA")
    if rev:
        return rev
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=_ROOT, capture_output=True, text=True, check=True
        ).stdout.strip()
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def _write_bench(path: str, gates: dict) -> None:
    payload = {"rev": _rev(), "generated_unix": int(time.time()), "gates": gates}
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"bench report written: {path}")


def main(
    budget_s: float,
    bucket_budget_s: float,
    shard_budget_s: float,
    kernel_budget_s: float,
    kernel_slowdown: float,
    min_tps: float,
    bench_out: str | None = None,
) -> None:
    gates = {
        "hybrid_enumeration": gate_hybrid_enumeration(budget_s),
        "bucketed_coroutines": gate_bucketed_coroutines(bucket_budget_s),
        "node_sharded_tick": gate_node_sharded_tick(shard_budget_s),
        "kernel_plane": gate_kernel_plane(kernel_budget_s, kernel_slowdown, min_tps),
    }
    if bench_out:
        _write_bench(bench_out, gates)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=float, default=300.0, help="2^6 enumeration budget (s)")
    ap.add_argument(
        "--bucket-budget", type=float, default=240.0, help="bucketed co-routine sweep budget (s)"
    )
    ap.add_argument(
        "--shard-budget", type=float, default=240.0, help="node-sharded tick gate budget (s)"
    )
    ap.add_argument(
        "--kernel-budget", type=float, default=600.0, help="kernel plane gate budget (s)"
    )
    ap.add_argument(
        "--kernel-slowdown",
        type=float,
        default=200.0,
        help="max allowed kernel-plane slowdown vs jnp (x); generous for interpret mode on CPU",
    )
    ap.add_argument(
        "--min-ticks-per-sec",
        type=float,
        default=5.0,
        help="jnp-plane warm-cache ticks/sec floor (roofline anchor)",
    )
    ap.add_argument(
        "--bench-out", default=None, help="write machine-readable BENCH_<rev>.json here"
    )
    add_device_args(ap)
    args = ap.parse_args()
    configure_devices(args, error=ap.error)
    main(
        args.budget,
        args.bucket_budget,
        args.shard_budget,
        args.kernel_budget,
        args.kernel_slowdown,
        args.min_ticks_per_sec,
        args.bench_out,
    )
