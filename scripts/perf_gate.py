"""CI perf-regression gate (bench-smoke job), driven through ``repro.api``.

Guards the planner/executor's load-bearing properties:

  1. single-compile: the paper's exhaustive 2^6 hybrid enumeration must run
     as ONE vmapped program.  ``plan()`` accounts for it
     (``ExecutionPlan.expected_compiles == 1``) and the measured jit-cache
     delta must match.  A protocol accidentally Python-branching on a
     traced knob silently falls back to 64 compilations — this gate
     catches it.
  2. bucketed static axes: a co-routine sweep whose points share one shape
     bucket must compile exactly ``expected_compiles`` (== n_buckets == 1)
     more programs, not one per config.  A regression in the bucketing
     planner or in the active-extent knob plumbing (EngineConfig.active_*)
     shows up as one compile per distinct static shape.
  3. node-sharded tick: the node-sharded engine must compile ONE SPMD
     program per mesh shape — every knob stays traced, so a family of
     configs on a fixed mesh shares the compiled sharded tick.
  4. wall-clock budgets: each sweep must finish inside its ``--budget``
     seconds end-to-end (compile + run).  The budgets are generous for
     slow CI runners; a per-cell-compile regression blows them by an
     order of magnitude.

Run from a fresh interpreter (the compile-cache assertions count programs
compiled in THIS process).
"""
import argparse
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks.common import add_device_args, configure_devices  # jax-free


def _measured_delta(before: dict, after: dict, cache: str):
    if before[cache] < 0 or after[cache] < 0:
        return None  # no introspection in this JAX version
    return after[cache] - before[cache]


def gate_hybrid_enumeration(budget_s: float) -> None:
    from repro import api

    spec = api.ExperimentSpec(
        protocol="sundial",
        workload="smallbank",
        configs=[{"hybrid": c} for c in api.all_hybrid_codes()],
        n_nodes=2, coroutines=12, records_per_node=4096, ticks=96, warmup=8,
    )
    pl = api.plan(spec)
    print(pl.summary())
    assert pl.expected_compiles == 1, (
        f"planner budgeted {pl.expected_compiles} compiles for the 2^6 enumeration (want 1)"
    )
    before = api.compile_stats()
    t0 = time.time()
    rows = api.execute(pl).rows
    wall = time.time() - t0
    assert len(rows) == 64 and all(r["commits"] > 0 for r in rows), "sweep produced bad rows"
    delta = _measured_delta(before, api.compile_stats(), pl.cache)
    if delta is not None:
        assert delta == pl.expected_compiles, (
            f"2^6 hybrid enumeration compiled {delta} programs "
            f"(planner budgeted {pl.expected_compiles}): a static/traced knob split regression"
        )
    assert wall < budget_s, f"hybrid enumeration took {wall:.1f}s (budget {budget_s:.0f}s)"
    compiles = (
        f"{delta} compile(s)" if delta is not None else "compile count UNCHECKED (no introspection)"
    )
    print(f"perf gate ok: 64-coding sweep = {compiles}, {wall:.1f}s < {budget_s:.0f}s budget")


def gate_bucketed_coroutines(budget_s: float) -> None:
    """A 4-point co-routine sweep inside one power-of-two shape bucket must
    cost exactly one compilation (== expected_compiles), not one per config."""
    from repro import api

    spec = api.ExperimentSpec(
        protocol="sundial",
        workload="smallbank",
        configs=[{"hybrid": 0b010101, "coroutines": c} for c in (10, 12, 14, 16)],
        n_nodes=2, coroutines=12, records_per_node=4096, ticks=96, warmup=8,
    )
    pl = api.plan(spec)
    print(pl.summary())
    assert pl.expected_compiles == 1, (
        f"4-point co-routine sweep planned {pl.expected_compiles} bucket(s)/compile(s) (want 1)"
    )
    before = api.compile_stats()
    t0 = time.time()
    rows = api.execute(pl).rows
    wall = time.time() - t0
    assert all(r["commits"] > 0 for r in rows), "bucketed sweep produced bad rows"
    assert [r["coroutines"] for r in rows] == [10, 12, 14, 16]
    assert rows[0]["n_buckets"] == 1
    delta = _measured_delta(before, api.compile_stats(), pl.cache)
    if delta is not None:
        assert delta == pl.expected_compiles, (
            f"bucketed co-routine sweep compiled {delta} programs "
            f"(planner budgeted {pl.expected_compiles} for {len(spec.configs)} configs): "
            "the bucketing planner or active-extent knobs regressed"
        )
        compiles = f"{delta} compile(s)"
    else:
        compiles = "compile count UNCHECKED (no introspection)"
    assert wall < budget_s, f"bucketed co-routine sweep took {wall:.1f}s (budget {budget_s:.0f}s)"
    print(
        f"perf gate ok: 4-point co-routine sweep = 1 bucket, "
        f"{compiles}, {wall:.1f}s < {budget_s:.0f}s budget"
    )


def gate_node_sharded_tick(budget_s: float) -> None:
    """The node-sharded engine must compile ONE SPMD program per mesh shape:
    every knob (hybrid coding, seed) stays traced through the api 'node'
    layout, so a family of configs on a fixed mesh shares the compiled
    sharded tick.  Runs on however many devices the process sees (1 in
    bench-smoke; the spmd-test job exercises the same contract on a
    4-fake-host mesh)."""
    from repro import api

    kw = dict(n_nodes=2, coroutines=12, records_per_node=4096, ticks=96, warmup=8)
    plans = [
        api.plan(
            api.ExperimentSpec(
                protocol="sundial", workload="smallbank", configs=(cfg,),
                node_shards=1, layout="node", **kw,
            )
        )
        for cfg in ({"hybrid": 0b010101}, {"hybrid": 0b101010}, {"seed": 7})
    ]
    assert all(pl.expected_compiles == 1 for pl in plans)
    before = api.compile_stats()
    t0 = time.time()
    rows = [api.execute(pl).row for pl in plans]
    wall = time.time() - t0
    assert all(r["commits"] > 0 for r in rows), "node-sharded cells produced bad rows"
    delta = _measured_delta(before, api.compile_stats(), "node")
    if delta is not None:
        # expected_compiles is a cold-cache bound per plan; the three plans
        # share one (GridSpec, mesh) program, so the measured total is 1
        assert delta == 1, (
            f"node-sharded tick compiled {delta} programs for 3 configs on one mesh "
            "(want 1): a knob leaked into the compiled program structure"
        )
        compiles = f"{delta} compile(s)"
    else:
        compiles = "compile count UNCHECKED (no introspection)"
    assert wall < budget_s, f"node-sharded cells took {wall:.1f}s (budget {budget_s:.0f}s)"
    print(f"perf gate ok: 3 node-sharded configs = {compiles}, {wall:.1f}s < {budget_s:.0f}s budget")


def main(budget_s: float, bucket_budget_s: float, shard_budget_s: float) -> None:
    gate_hybrid_enumeration(budget_s)
    gate_bucketed_coroutines(bucket_budget_s)
    gate_node_sharded_tick(shard_budget_s)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=float, default=300.0, help="2^6 enumeration budget (s)")
    ap.add_argument(
        "--bucket-budget", type=float, default=240.0, help="bucketed co-routine sweep budget (s)"
    )
    ap.add_argument(
        "--shard-budget", type=float, default=240.0, help="node-sharded tick gate budget (s)"
    )
    add_device_args(ap)
    args = ap.parse_args()
    configure_devices(args, error=ap.error)
    main(args.budget, args.bucket_budget, args.shard_budget)
