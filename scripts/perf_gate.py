"""CI perf-regression gate (bench-smoke job).

Guards the batched sweep engine's two load-bearing properties:

  1. single-compile: the paper's exhaustive 2^6 hybrid enumeration must run
     as ONE vmapped program (``sweep.compile_cache_size() == 1`` in a fresh
     process).  A protocol accidentally Python-branching on a traced knob
     silently falls back to 64 compilations — this gate catches it.
  2. wall-clock budget: the enumeration must finish inside ``--budget``
     seconds end-to-end (compile + run).  The budget is generous for slow
     CI runners; a per-cell-compile regression blows it by an order of
     magnitude.

Run from a fresh interpreter (the compile-cache assertion counts programs
compiled in THIS process).
"""
import argparse
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.core import sweep
from repro.core.sweep import all_hybrid_codes, run_grid


def main(budget_s: float) -> None:
    kw = dict(n_nodes=2, coroutines=12, records_per_node=4096, ticks=96, warmup=8)
    t0 = time.time()
    rows = run_grid("sundial", "smallbank", [{"hybrid": c} for c in all_hybrid_codes()], **kw)
    wall = time.time() - t0
    assert len(rows) == 64 and all(r["commits"] > 0 for r in rows), "sweep produced bad rows"
    n_compiles = sweep.compile_cache_size()
    if n_compiles >= 0:  # introspection available in this JAX version
        assert n_compiles == 1, (
            f"2^6 hybrid enumeration compiled {n_compiles} programs (want 1): "
            "a static/traced knob split regression"
        )
    assert wall < budget_s, f"hybrid enumeration took {wall:.1f}s (budget {budget_s:.0f}s)"
    compiles = f"{n_compiles} compile(s)" if n_compiles >= 0 else "compile count UNCHECKED (no introspection)"
    print(f"perf gate ok: 64-coding sweep = {compiles}, {wall:.1f}s < {budget_s:.0f}s budget")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=float, default=300.0, help="wall-clock budget (s)")
    args = ap.parse_args()
    main(args.budget)
