"""CI perf-regression gate (bench-smoke job).

Guards the batched sweep engine's load-bearing properties:

  1. single-compile: the paper's exhaustive 2^6 hybrid enumeration must run
     as ONE vmapped program (``sweep.compile_cache_size() == 1`` in a fresh
     process).  A protocol accidentally Python-branching on a traced knob
     silently falls back to 64 compilations — this gate catches it.
  2. bucketed static axes: a co-routine sweep whose points share one shape
     bucket must compile exactly ``n_buckets`` (== 1) more programs, not
     one per config.  A regression in the bucketing planner or in the
     active-extent knob plumbing (EngineConfig.active_*) shows up as one
     compile per distinct static shape.
  3. wall-clock budgets: both sweeps must finish inside their ``--budget``/
     ``--bucket-budget`` seconds end-to-end (compile + run).  The budgets
     are generous for slow CI runners; a per-cell-compile regression blows
     them by an order of magnitude.

Run from a fresh interpreter (the compile-cache assertions count programs
compiled in THIS process).
"""
import argparse
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.core import sweep
from repro.core.sweep import all_hybrid_codes, run_grid


def gate_hybrid_enumeration(budget_s: float) -> None:
    kw = dict(n_nodes=2, coroutines=12, records_per_node=4096, ticks=96, warmup=8)
    t0 = time.time()
    rows = run_grid("sundial", "smallbank", [{"hybrid": c} for c in all_hybrid_codes()], **kw)
    wall = time.time() - t0
    assert len(rows) == 64 and all(r["commits"] > 0 for r in rows), "sweep produced bad rows"
    n_compiles = sweep.compile_cache_size()
    if n_compiles >= 0:  # introspection available in this JAX version
        assert n_compiles == 1, (
            f"2^6 hybrid enumeration compiled {n_compiles} programs (want 1): "
            "a static/traced knob split regression"
        )
    assert wall < budget_s, f"hybrid enumeration took {wall:.1f}s (budget {budget_s:.0f}s)"
    compiles = f"{n_compiles} compile(s)" if n_compiles >= 0 else "compile count UNCHECKED (no introspection)"
    print(f"perf gate ok: 64-coding sweep = {compiles}, {wall:.1f}s < {budget_s:.0f}s budget")


def gate_bucketed_coroutines(budget_s: float) -> None:
    """A 4-point co-routine sweep inside one power-of-two shape bucket must
    cost exactly one compilation (== n_buckets), not one per config."""
    before = sweep.compile_cache_size()
    cfgs = [{"hybrid": 0b010101, "coroutines": c} for c in (10, 12, 14, 16)]
    t0 = time.time()
    rows = run_grid(
        "sundial", "smallbank", cfgs,
        n_nodes=2, coroutines=12, records_per_node=4096, ticks=96, warmup=8,
    )
    wall = time.time() - t0
    assert all(r["commits"] > 0 for r in rows), "bucketed sweep produced bad rows"
    assert [r["coroutines"] for r in rows] == [10, 12, 14, 16]
    n_buckets = rows[0]["n_buckets"]
    assert n_buckets == 1, f"4-point co-routine sweep planned {n_buckets} buckets (want 1)"
    after = sweep.compile_cache_size()
    if before >= 0 and after >= 0:
        delta = after - before
        assert delta == n_buckets, (
            f"bucketed co-routine sweep compiled {delta} programs for {n_buckets} bucket(s) "
            f"/ {len(cfgs)} configs: the bucketing planner or active-extent knobs regressed"
        )
        compiles = f"{delta} compile(s)"
    else:
        compiles = "compile count UNCHECKED (no introspection)"
    assert wall < budget_s, f"bucketed co-routine sweep took {wall:.1f}s (budget {budget_s:.0f}s)"
    print(
        f"perf gate ok: 4-point co-routine sweep = {n_buckets} bucket(s), "
        f"{compiles}, {wall:.1f}s < {budget_s:.0f}s budget"
    )


def gate_node_sharded_tick(budget_s: float) -> None:
    """The node-sharded engine must compile ONE SPMD program per mesh shape:
    every knob (hybrid coding, seed) stays traced through run_cell_sharded,
    so a family of configs on a fixed mesh shares the compiled sharded tick.
    Runs on however many devices the process sees (1 in bench-smoke; the
    spmd-test job exercises the same contract on a 4-fake-host mesh)."""
    before = sweep.node_sharded_compile_count()
    kw = dict(n_nodes=2, coroutines=12, records_per_node=4096, ticks=96, warmup=8)
    t0 = time.time()
    rows = [
        sweep.run_cell_sharded("sundial", "smallbank", cfg, node_shards=1, **kw)
        for cfg in ({"hybrid": 0b010101}, {"hybrid": 0b101010}, {"seed": 7})
    ]
    wall = time.time() - t0
    assert all(r["commits"] > 0 for r in rows), "node-sharded cells produced bad rows"
    after = sweep.node_sharded_compile_count()
    if before >= 0 and after >= 0:
        delta = after - before
        assert delta == 1, (
            f"node-sharded tick compiled {delta} programs for 3 configs on one mesh "
            "(want 1): a knob leaked into the compiled program structure"
        )
        compiles = f"{delta} compile(s)"
    else:
        compiles = "compile count UNCHECKED (no introspection)"
    assert wall < budget_s, f"node-sharded cells took {wall:.1f}s (budget {budget_s:.0f}s)"
    print(f"perf gate ok: 3 node-sharded configs = {compiles}, {wall:.1f}s < {budget_s:.0f}s budget")


def main(budget_s: float, bucket_budget_s: float, shard_budget_s: float) -> None:
    gate_hybrid_enumeration(budget_s)
    gate_bucketed_coroutines(bucket_budget_s)
    gate_node_sharded_tick(shard_budget_s)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=float, default=300.0, help="2^6 enumeration budget (s)")
    ap.add_argument(
        "--bucket-budget", type=float, default=240.0, help="bucketed co-routine sweep budget (s)"
    )
    ap.add_argument(
        "--shard-budget", type=float, default=240.0, help="node-sharded tick gate budget (s)"
    )
    args = ap.parse_args()
    main(args.budget, args.bucket_budget, args.shard_budget)
