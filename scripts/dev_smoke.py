"""Dev smoke: protocol-engine matrix + reduced LM configs on 1 CPU device.

``--fast`` runs the protocol matrix through the batched sweep engine (one
compiled grid per protocol instead of one jit per (protocol, plane) cell)
and is what CI's quick job uses.
"""
import argparse
import os
import sys

# runnable as `python scripts/dev_smoke.py` from a checkout
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

# device flags are parsed (benchmarks.common, jax-free) before any heavy
# import below pulls in jax — fake-host forcing must come first
B, S = 2, 64


def batch_for(cfg):
    import jax
    import jax.numpy as jnp

    key = jax.random.PRNGKey(0)
    b = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.encoder_decoder:
        b["frames"] = jax.random.normal(key, (B, cfg.enc_seq_len, cfg.d_model), jnp.float32)
    if cfg.mrope_sections is not None:
        b["positions"] = jnp.broadcast_to(jnp.arange(S)[None, None], (B, 3, S)).astype(jnp.int32)
    return b


def protocol_matrix(fast: bool) -> None:
    """Every REGISTERED protocol x {rpc, one-sided} commits transactions."""
    from repro.api import ExperimentSpec, run
    from repro.core.costmodel import ONE_SIDED, RPC
    from repro.core.registry import protocol_names

    kw = dict(n_nodes=2, coroutines=6, records_per_node=256, ticks=48, warmup=8)
    planes = [{"hybrid": (RPC,) * 6}, {"hybrid": (ONE_SIDED,) * 6}]
    for proto in protocol_names():
        if fast:
            # one compiled 2-config grid per protocol, planned by repro.api
            rows = run(
                ExperimentSpec(protocol=proto, workload="smallbank", configs=planes, **kw)
            ).rows
        else:
            # true sequential reference (static hybrid, one jit per cell)
            from benchmarks.common import run_cell

            rows = [run_cell(proto, "smallbank", p["hybrid"], **kw)[0] for p in planes]
        for impl, m in zip(("rpc", "one_sided"), rows):
            assert m["commits"] > 0, (proto, impl, m)
            assert m["abort_rate"] < 1.0, (proto, impl, m)
        print(
            f"    {proto}: ok (commits rpc={rows[0]['commits']} "
            f"one_sided={rows[1]['commits']})",
            flush=True,
        )
    print("protocol matrix ok", flush=True)


def main(arch_ids):
    import jax
    import jax.numpy as jnp

    from repro.configs import reduced_config
    from repro.models.decode import lm_decode_step, lm_prefill
    from repro.models.lm import init_lm, lm_apply
    from repro.sharding import AxisRules, unzip_params
    from repro.train.steps import build_train_step

    shd = AxisRules(None)
    for aid in arch_ids:
        cfg = reduced_config(aid)
        print(f"--- {aid}: {cfg.family} params={cfg.param_count():,}", flush=True)
        params = unzip_params(init_lm(jax.random.PRNGKey(1), cfg, jnp.float32))[0]
        batch = batch_for(cfg)
        logits = jax.jit(lambda p, b: lm_apply(p, cfg, shd, b))(params, batch)
        assert logits.shape == (B, S, cfg.vocab_size), logits.shape
        assert bool(jnp.isfinite(logits).all()), "NaN in logits"
        print("    forward ok", flush=True)

        train_step, opt = build_train_step(cfg, shd, "adamw")
        opt_state = opt.init(params)
        p2, o2, metrics = jax.jit(train_step)(params, opt_state, jnp.int32(0), batch)
        assert bool(jnp.isfinite(metrics["loss"])), metrics
        print(f"    train ok loss={float(metrics['loss']):.3f}", flush=True)

        pre_batch = dict(batch)
        pre_batch.pop("labels")
        logits1, cache = jax.jit(lambda p, b: lm_prefill(p, cfg, shd, b))(params, pre_batch)
        assert logits1.shape == (B, cfg.vocab_size)
        db = {"token": jnp.zeros((B,), jnp.int32)}
        if cfg.mrope_sections is not None:
            db["positions"] = jnp.full((B, 3), S, jnp.int32)
        logits2, cache2 = jax.jit(lambda p, c, b: lm_decode_step(p, cfg, shd, c, b))(
            params, cache, db
        )
        assert logits2.shape == (B, cfg.vocab_size)
        assert bool(jnp.isfinite(logits2).all())
        assert int(cache2["len"]) == S + 1
        print("    prefill+decode ok", flush=True)

        # consistency: prefill(4) logits == full[:,3]; decode(tok4) == full[:,4]
        Tp = 4
        fb = {"tokens": batch["tokens"][:, : Tp + 1]}
        if "frames" in batch:
            fb["frames"] = batch["frames"]
        if "positions" in batch:
            fb["positions"] = batch["positions"][:, :, : Tp + 1]
        full = lm_apply(params, cfg, shd, fb)
        pb = {"tokens": fb["tokens"][:, :Tp]}
        if "frames" in fb:
            pb["frames"] = fb["frames"]
        if "positions" in fb:
            pb["positions"] = fb["positions"][:, :, :Tp]
        lg_p, c = lm_prefill(params, cfg, shd, pb, pad_to=Tp + 4)
        err_p = float(jnp.abs(lg_p - full[:, Tp - 1]).max())
        dbt = {"token": fb["tokens"][:, Tp]}
        if cfg.mrope_sections is not None:
            dbt["positions"] = jnp.full((B, 3), Tp, jnp.int32)
        lg_d, c = lm_decode_step(params, cfg, shd, c, dbt)
        err_d = float(jnp.abs(lg_d - full[:, Tp]).max())
        print(f"    prefill-vs-forward={err_p:.2e} decode-vs-forward={err_d:.2e}", flush=True)
        assert err_p < 2e-2 and err_d < 2e-2, (err_p, err_d)
    print("ALL OK")


if __name__ == "__main__":
    from benchmarks.common import add_device_args, configure_devices

    ap = argparse.ArgumentParser()
    ap.add_argument("arch_ids", nargs="*", help="LM arch ids (default: all)")
    ap.add_argument(
        "--fast", action="store_true", help="batched sweep for the protocol matrix"
    )
    ap.add_argument("--skip-lm", action="store_true", help="protocol matrix only")
    add_device_args(ap)
    args = ap.parse_args()
    configure_devices(args, error=ap.error)
    print(f"--- protocol matrix ({'batched' if args.fast else 'sequential'})", flush=True)
    protocol_matrix(args.fast)
    if not args.skip_lm:
        from repro.configs import ARCH_IDS

        main(args.arch_ids or list(ARCH_IDS))
