"""API-boundary gate: benchmarks/examples/scripts go through the front door.

Everything outside ``src/repro`` (and ``tests/``, which pins the legacy
shims on purpose) must reach the engine through ``repro.api`` or
``repro.core.registry`` — never through the deprecated sweep entry points
or the legacy ``PROTOCOLS`` dict.  CI runs this in the lint job; it is
also executed by tests/test_api.py so the gate holds offline.

The scan is AST-based (imports, names, attribute access), so prose in
comments or docstrings that merely *mentions* the banned names does not
trip it.

A second gate keeps ``src/repro/kernels/`` honest: every kernel module
must be imported somewhere outside the kernels package (src/repro,
benchmarks, examples or scripts — tests alone don't count), directly or
transitively through another live kernel module.  A Pallas kernel that
only its own test imports is dead freight that silently drifts from the
engine's semantics; delete it or wire it into the kernel plane
(``repro.kernels.ops``).  ``__init__.py`` and ``ref.py`` (the pure-jnp
oracle set, imported by tests and the jnp dispatch path by design) are
exempt.

Exit 0 = clean; exit 1 = prints one line per violation.
"""
from __future__ import annotations

import ast
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCAN_DIRS = ("benchmarks", "examples", "scripts")
SELF = os.path.join("scripts", "check_api_boundary.py")

SWEEP_MODULE = "repro.core.sweep"
BANNED_NAMES = {"PROTOCOLS"}
SWEEP_ENTRY_POINTS = {"run_grid", "run_grid_sharded", "run_cell_sharded", "plan_buckets"}

KERNELS_PKG = "repro.kernels"
KERNEL_LIVE_DIRS = (os.path.join("src", "repro"), "benchmarks", "examples", "scripts")
KERNEL_EXEMPT = {"__init__", "ref"}


def _file_violations(path: str, rel: str):
    tree = ast.parse(open(path).read(), filename=rel)
    out = []

    def flag(node, what):
        out.append(f"{rel}:{node.lineno}: banned API use: {what}")

    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod == SWEEP_MODULE or mod.startswith(SWEEP_MODULE + "."):
                flag(node, f"from {mod} import ... (use repro.api)")
            elif any(a.name in BANNED_NAMES for a in node.names):
                flag(node, f"from {mod} import PROTOCOLS (use repro.core.registry)")
            elif mod == "repro.core" and any(a.name == "sweep" for a in node.names):
                flag(node, "from repro.core import sweep (use repro.api)")
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name == SWEEP_MODULE or a.name.startswith(SWEEP_MODULE + "."):
                    flag(node, f"import {a.name} (use repro.api)")
        elif isinstance(node, ast.Name) and node.id in BANNED_NAMES:
            flag(node, "PROTOCOLS (use repro.core.registry.get_protocol)")
        elif (
            isinstance(node, ast.Attribute)
            and node.attr in SWEEP_ENTRY_POINTS
            and isinstance(node.value, ast.Name)
            and node.value.id == "sweep"
        ):
            flag(node, f"sweep.{node.attr} (use repro.api.plan/execute)")
    return out


def violations(root: str = ROOT):
    out = []
    for d in SCAN_DIRS:
        for dirpath, _, files in os.walk(os.path.join(root, d)):
            for fn in sorted(files):
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                rel = os.path.relpath(path, root)
                if rel == SELF:
                    continue
                out.extend(_file_violations(path, rel))
    return out


def _kernel_imports(path: str) -> set[str]:
    """Kernel submodule names a file imports (AST walk, so lazy function-level
    imports count too — the jnp dispatch path imports ref lazily by design)."""
    tree = ast.parse(open(path).read(), filename=path)
    out = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name.startswith(KERNELS_PKG + "."):
                    out.add(a.name.split(".")[2])
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod == KERNELS_PKG:
                out.update(a.name for a in node.names)  # from repro.kernels import ops
            elif mod.startswith(KERNELS_PKG + "."):
                out.add(mod.split(".")[2])
    return out


def kernel_liveness(root: str = ROOT):
    """Dead-module gate: one violation line per kernel module that nothing
    outside the kernels package reaches, directly or transitively."""
    kdir = os.path.join(root, "src", "repro", "kernels")
    if not os.path.isdir(kdir):
        return []
    modules = {fn[:-3] for fn in os.listdir(kdir) if fn.endswith(".py")}
    internal = {
        m: _kernel_imports(os.path.join(kdir, m + ".py")) & modules
        for m in modules - {"__init__"}
    }
    live = set()
    for d in KERNEL_LIVE_DIRS:
        for dirpath, _, files in os.walk(os.path.join(root, d)):
            if os.path.abspath(dirpath).startswith(os.path.abspath(kdir)):
                continue
            for fn in sorted(files):
                if fn.endswith(".py"):
                    live |= _kernel_imports(os.path.join(dirpath, fn)) & modules
    frontier = set(live)
    while frontier:  # transitive: a module a live module imports is live
        frontier = set().union(*(internal.get(m, set()) for m in frontier)) - live
        live |= frontier
    return [
        f"src/repro/kernels/{m}.py: dead kernel module — imported nowhere in "
        f"{'/'.join(KERNEL_LIVE_DIRS)} (tests don't count); wire it into "
        "repro.kernels.ops or delete it"
        for m in sorted(modules - live - KERNEL_EXEMPT)
    ]


def main() -> int:
    bad = violations()
    for v in bad:
        print(v)
    if bad:
        print(
            f"\n{len(bad)} API-boundary violation(s): use repro.api "
            "(ExperimentSpec/plan/execute) or repro.core.registry instead",
            file=sys.stderr,
        )
        return 1
    dead = kernel_liveness()
    for v in dead:
        print(v)
    if dead:
        print(f"\n{len(dead)} dead kernel module(s)", file=sys.stderr)
        return 1
    print("api boundary ok: no direct sweep.run_*/PROTOCOLS use outside src/repro")
    print("kernel liveness ok: every src/repro/kernels module is reachable")
    return 0


if __name__ == "__main__":
    sys.exit(main())
