"""Paper §5: hybrid designs — cherry-picked per protocol + exhaustive
enumeration of all 2^6 stage codings for one (protocol, workload)."""
from __future__ import annotations

from repro.core.costmodel import N_HYBRID_STAGES, ONE_SIDED, RPC, STAGE_NAMES

from benchmarks.common import PROTO_LIST, cherry_pick_hybrid, run_cell


def main(full: bool = False, exhaustive_proto: str = "sundial", exhaustive_wl: str = "smallbank"):
    rows = []
    print("hybrid,protocol,workload,code,throughput_ktps,latency_us,note")
    # cherry-picked hybrids for every protocol
    for proto in PROTO_LIST:
        for wl in ("smallbank", "ycsb") if full else ("smallbank",):
            code, m_rpc, m_os = cherry_pick_hybrid(proto, wl, ticks=240)
            m_h, _, _ = run_cell(proto, wl, code, ticks=240)
            best_pure = max(m_rpc["throughput_mtps"], m_os["throughput_mtps"])
            gain = (m_h["throughput_mtps"] - best_pure) / best_pure * 100
            for nm, m in (("rpc", m_rpc), ("one_sided", m_os), ("cherry", m_h)):
                print(
                    f"hybrid,{proto},{wl},{m['hybrid']},{m['throughput_mtps']*1e3:.1f},"
                    f"{m['avg_latency_us']:.2f},{nm}{f' gain={gain:+.1f}%' if nm=='cherry' else ''}"
                )
            rows.append((proto, wl, code, m_h, gain))
    # exhaustive enumeration for one pair
    if full:
        best = None
        for code_int in range(2 ** N_HYBRID_STAGES):
            m, _, _ = run_cell(exhaustive_proto, exhaustive_wl, code_int, ticks=160, coroutines=40)
            if best is None or m["throughput_mtps"] > best["throughput_mtps"]:
                best = m
            print(
                f"hybrid_exhaustive,{exhaustive_proto},{exhaustive_wl},{m['hybrid']},"
                f"{m['throughput_mtps']*1e3:.1f},{m['avg_latency_us']:.2f},"
            )
        print(
            f"hybrid_best,{exhaustive_proto},{exhaustive_wl},{best['hybrid']},"
            f"{best['throughput_mtps']*1e3:.1f},{best['avg_latency_us']:.2f},exhaustive-argmax"
        )
    return rows


if __name__ == "__main__":
    main()
