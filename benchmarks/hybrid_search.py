"""Paper §5: hybrid designs — cherry-picked per protocol + exhaustive
enumeration of all 2^6 stage codings for one (protocol, workload).

The exhaustive enumeration runs as ONE vmapped program (the repro.api
planner), so it is cheap enough to run at CI sizes by default; ``--full``
only scales the simulation, not the number of compilations (always 1 for
the grid).  On multi-device hosts (or fake-host CPU meshes) the 64-coding
grid is additionally sharded over the device axis (``devices="auto"``).
"""
from __future__ import annotations

from repro.api import ExperimentSpec, all_hybrid_codes, run

from benchmarks.common import PROTO_LIST, cherry_pick_hybrid


def _grid(proto, wl, configs, **kw):
    return run(ExperimentSpec(protocol=proto, workload=wl, configs=configs, **kw)).rows


def main(full: bool = False, exhaustive_proto: str = "sundial", exhaustive_wl: str = "smallbank"):
    rows = []
    print("hybrid,protocol,workload,code,throughput_ktps,latency_us,note")
    cell_kw = (
        dict(ticks=240)
        if full
        else dict(ticks=120, coroutines=20, records_per_node=8192)
    )
    # cherry-picked hybrids for every protocol
    for proto in PROTO_LIST:
        for wl in ("smallbank", "ycsb") if full else ("smallbank",):
            code, m_rpc, m_os = cherry_pick_hybrid(proto, wl, **cell_kw)
            (m_h,) = _grid(proto, wl, [{"hybrid": code}], **cell_kw)
            best_pure = max(m_rpc["throughput_mtps"], m_os["throughput_mtps"])
            gain = (m_h["throughput_mtps"] - best_pure) / max(best_pure, 1e-9) * 100
            for nm, m in (("rpc", m_rpc), ("one_sided", m_os), ("cherry", m_h)):
                print(
                    f"hybrid,{proto},{wl},{m['hybrid']},{m['throughput_mtps']*1e3:.1f},"
                    f"{m['avg_latency_us']:.2f},{nm}{f' gain={gain:+.1f}%' if nm=='cherry' else ''}"
                )
            rows.append((proto, wl, code, m_h, gain))
    # exhaustive enumeration for one pair: 64 codings, ONE compilation
    ex_kw = (
        dict(ticks=160, coroutines=40)
        if full
        else dict(ticks=96, coroutines=12, records_per_node=4096)
    )
    ms = _grid(
        exhaustive_proto, exhaustive_wl, [{"hybrid": c} for c in all_hybrid_codes()],
        devices="auto", **ex_kw
    )
    best = max(ms, key=lambda m: m["throughput_mtps"])
    for m in ms:
        print(
            f"hybrid_exhaustive,{exhaustive_proto},{exhaustive_wl},{m['hybrid']},"
            f"{m['throughput_mtps']*1e3:.1f},{m['avg_latency_us']:.2f},"
        )
    print(
        f"hybrid_best,{exhaustive_proto},{exhaustive_wl},{best['hybrid']},"
        f"{best['throughput_mtps']*1e3:.1f},{best['avg_latency_us']:.2f},"
        f"exhaustive-argmax wall_s={best['wall_s']}"
    )

    # cross-stage doorbell merging (§4.2, rounds.fuse_log_commit): re-run the
    # same 2^6 enumeration with merging enabled — codings with LOG and COMMIT
    # both one-sided post them as ONE doorbell (one MMIO, one RTT, one fewer
    # round) — and report the best FUSED mixed coding against both pures.
    ms_m = _grid(
        exhaustive_proto,
        exhaustive_wl,
        [{"hybrid": c} for c in all_hybrid_codes()],
        merge_stages=True,
        devices="auto",
        **ex_kw,
    )
    pure = max(ms_m[0]["throughput_mtps"], ms_m[-1]["throughput_mtps"])
    mixed = [m for m in ms_m if m["hybrid"] not in ("000000", "111111")]
    best_m = max(mixed, key=lambda m: m["throughput_mtps"])
    gain_m = (best_m["throughput_mtps"] - pure) / max(pure, 1e-9) * 100
    for nm, m in (("pure_rpc", ms_m[0]), ("pure_one_sided", ms_m[-1]), ("fused_hybrid", best_m)):
        print(
            f"hybrid_merged,{exhaustive_proto},{exhaustive_wl},{m['hybrid']},"
            f"{m['throughput_mtps']*1e3:.1f},{m['avg_latency_us']:.2f},{nm}"
        )
    print(
        f"hybrid_merged_best,{exhaustive_proto},{exhaustive_wl},{best_m['hybrid']},"
        f"{best_m['throughput_mtps']*1e3:.1f},{best_m['avg_latency_us']:.2f},"
        f"fused-beats-pure={best_m['throughput_mtps'] > pure} gain={gain_m:+.1f}%"
    )

    # write-heavy OCC's VALIDATE→LOG merge-table pair (rounds.MERGE_TABLE):
    # a coding with VALIDATE+LOG one-sided but COMMIT two-sided can ONLY
    # fuse through the validation doorbell, so the merged-vs-unmerged delta
    # isolates the new pair.  merge_stages is static in GridSpec, so the
    # off/on cells are two 1-config grids (two compilations).
    vl_code = 0b001100  # bits: validate(2) + log(3) one-sided, rest RPC
    (m_vl_off,) = _grid("occ", exhaustive_wl, [{"hybrid": vl_code}], **ex_kw)
    (m_vl_on,) = _grid(
        "occ", exhaustive_wl, [{"hybrid": vl_code}], merge_stages=True, **ex_kw
    )
    gain_vl = (
        (m_vl_on["throughput_mtps"] - m_vl_off["throughput_mtps"])
        / max(m_vl_off["throughput_mtps"], 1e-9) * 100
    )
    for nm, m in (("validate_log_off", m_vl_off), ("validate_log_on", m_vl_on)):
        print(
            f"hybrid_merged,occ,{exhaustive_wl},{m['hybrid']},"
            f"{m['throughput_mtps']*1e3:.1f},{m['avg_latency_us']:.2f},{nm}"
        )
    print(
        f"hybrid_merged_best,occ,{exhaustive_wl},{m_vl_on['hybrid']},"
        f"{m_vl_on['throughput_mtps']*1e3:.1f},{m_vl_on['avg_latency_us']:.2f},"
        f"validate_log gain={gain_vl:+.1f}%"
    )
    return rows


if __name__ == "__main__":
    main()
