"""Roofline analysis (harness deliverable g).

Combines:
  * full-cell scanned dry-run records (memory analysis, collective schedule,
    compile proof)           — dryrun_results.json
  * unrolled small-L calibration lowerings extrapolated to full depth
    (exact per-device HLO flops / bytes / collective bytes)
                              — calib_results.json

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

  compute term    = HLO_FLOPs / peak_FLOPs          [s, per device]
  memory term     = HLO_bytes / HBM_bw              [s, per device]
  collective term = collective_bytes / link_bw      [s, per device]

MODEL_FLOPS = 6*N_active*tokens (train) or 2*N_active*tokens (+ attention
terms) — the useful-compute numerator for the waste ratio.
"""
from __future__ import annotations

import json
import os
from typing import Dict

from repro.configs import SHAPES, get_config
from repro.configs.base import ArchConfig, ShapeSpec

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
HBM_BYTES = 16e9
N_DEV = 256


def attn_flops_fwd(cfg: ArchConfig, B: int, S: int, cache: int = 0) -> float:
    """Attention score+value matmul flops (fwd), causal-aware, per step."""
    H, Dh = cfg.n_heads, cfg.head_dim
    kinds = cfg.layer_kinds()
    total = 0.0
    for kind in kinds:
        if kind == "attn":
            w = cfg.local_window if (cfg.is_hybrid and cfg.local_window) else 0
            if cache:  # decode: q(1) x K(cache)
                eff = min(w, cache) if w else cache
                total += 4.0 * B * H * Dh * eff
            else:
                eff = S * min(w, S) if w else S * S / 2.0
                total += 4.0 * B * H * Dh * eff
    if cfg.encoder_decoder:
        Se = cfg.enc_seq_len
        total += cfg.n_enc_layers * 4.0 * B * H * Dh * Se * Se  # bidirectional
        if cache:
            total += cfg.n_layers * 4.0 * B * H * Dh * Se  # cross-attn decode
        else:
            total += cfg.n_layers * 4.0 * B * H * Dh * S * Se
    return total


def _matmul_params(cfg: ArchConfig, decode: bool = False) -> float:
    """Active params participating in per-token matmuls, EXCLUDING the
    embedding lookup (a gather) and the LM head (counted separately since
    prefill/decode apply it to far fewer positions than the backbone)."""
    V, D = cfg.vocab_size, cfg.d_model
    body = float(cfg.active_param_count()) - V * D  # embed table
    if not cfg.tie_embeddings:
        body -= V * D  # lm head counted separately
    if decode and cfg.encoder_decoder:
        H, KV, Dh, F = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_ff
        n_mat = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
        enc = cfg.n_enc_layers * (2 * D * H * Dh + 2 * D * KV * Dh + n_mat * D * F)
        cross_kv = cfg.n_layers * 2 * D * KV * Dh  # cached at prefill
        body -= enc + cross_kv
    return max(body, 0.0)


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """Useful flops per step, whole cluster."""
    V, D = cfg.vocab_size, cfg.d_model
    head = float(V) * D
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 6.0 * (_matmul_params(cfg) + head) * B * S + 3.0 * attn_flops_fwd(cfg, B, S)
    if shape.kind == "prefill":
        # head applies to the LAST position only (lm_prefill semantics)
        return 2.0 * _matmul_params(cfg) * B * S + 2.0 * head * B + attn_flops_fwd(cfg, B, S)
    return (
        2.0 * (_matmul_params(cfg, decode=True) + head) * B
        + attn_flops_fwd(cfg, B, 1, cache=S)
    )


def model_memory_bytes(cfg: ArchConfig, shape: ShapeSpec, param_bytes_dev: float) -> float:
    """Fused-execution HBM-traffic estimate per device (the HLO
    'bytes accessed' counts every op unfused and wildly overstates traffic;
    this is the engineering lower bound the §Perf loop drives toward).

    train:  weights 3x/micro (fwd + remat-fwd + bwd) + grad accum r/w +
            optimizer update + ~8 passes over layer activations
    prefill: weights 1x + 4 activation passes + KV-cache write
    decode:  active weights 1x + KV-cache read (the roofline term for decode)
    """
    B, S = shape.global_batch, shape.seq_len
    D, L = cfg.d_model, cfg.n_layers
    kv_bytes_dev = 0.0
    if not cfg.is_ssm:
        n_attn = sum(1 for k in cfg.layer_kinds() if k == "attn")
        width = min(cfg.local_window or S, S) if cfg.is_hybrid else S
        kv_bytes_dev = 2.0 * B * width * cfg.n_kv_heads * cfg.head_dim * 2 * n_attn / N_DEV
    if shape.kind == "train":
        n_micro = cfg.microbatch
        tok_dev = B * S / N_DEV
        opt_factor = 24.0 if cfg.optimizer == "adamw" else 10.0
        w = param_bytes_dev * (3.0 * n_micro + 4.0) + param_bytes_dev / 2.0 * opt_factor
        acts = 8.0 * L * tok_dev * D * 2.0
        return w + acts
    if shape.kind == "prefill":
        tok_dev = B * S / N_DEV
        active_ratio = cfg.active_param_count() / cfg.param_count()
        return param_bytes_dev * active_ratio + 4.0 * L * tok_dev * D * 2.0 + kv_bytes_dev
    active_ratio = cfg.active_param_count() / cfg.param_count()
    if cfg.is_moe:  # only experts routed to this batch's tokens are touched
        touched = min(1.0, active_ratio * max(B, 1))
        active_ratio = min(1.0, touched)
    return param_bytes_dev * active_ratio + kv_bytes_dev


def analyze(dryrun_path: str, calib_path: str):
    with open(dryrun_path) as f:
        dry = {(r["arch"], r["shape"], r["mesh"]): r for r in json.load(f)}
    calib = {}
    if os.path.exists(calib_path):
        with open(calib_path) as f:
            calib = {(r["arch"], r["shape"]): r for r in json.load(f)}

    rows = []
    for (arch, shape_name, mesh), r in sorted(dry.items()):
        if mesh != "16x16" or r["status"] != "ok":
            continue
        cfg, _ = get_config(arch)
        shape = SHAPES[shape_name]
        c = calib.get((arch, shape_name))
        row: Dict = {"arch": arch, "shape": shape_name}
        mf = model_flops(cfg, shape) / N_DEV
        row["model_flops_per_dev"] = mf
        if c and c.get("status") == "ok":
            pd = c["per_device"]
            row["hlo_flops"] = pd["flops"]
            row["hlo_bytes"] = pd["bytes"]
            # CPU backend upcasts bf16 to f32: f32 collective bytes are
            # logically bf16 on the TPU target -> halve that component.
            # (When the split wasn't tracked, assume all-f32 — measured
            # splits show >95% of collective bytes are f32-on-CPU.)
            f32 = pd.get("coll_f32") or pd["coll"]
            row["coll_bytes"] = pd["coll"] - 0.5 * f32
            row["param_bytes_per_dev"] = c.get("param_bytes_per_device", 0)
        else:  # fall back to the (scan-undercounted) full-cell numbers
            row["hlo_flops"] = r["cost"].get("flops", 0.0)
            row["hlo_bytes"] = r["cost"].get("bytes accessed", 0.0)
            row["coll_bytes"] = float(r["collectives"]["total_bytes"])
            row["param_bytes_per_dev"] = 0
            row["calib"] = "MISSING (scan-undercounted)"
        row["compute_s"] = row["hlo_flops"] / PEAK_FLOPS
        row["memory_s_hlo"] = row["hlo_bytes"] / HBM_BW  # unfused upper bound
        row["memory_s"] = model_memory_bytes(cfg, shape, row["param_bytes_per_dev"]) / HBM_BW
        row["collective_s"] = row["coll_bytes"] / LINK_BW
        terms = {
            "compute": row["compute_s"],
            "memory": row["memory_s"],
            "collective": row["collective_s"],
        }
        row["bottleneck"] = max(terms, key=terms.get)
        row["useful_ratio"] = mf / max(row["hlo_flops"], 1.0)
        bound = max(terms.values())
        row["roofline_frac"] = row["compute_s"] / bound if bound else 0.0
        mem = r.get("memory", {})
        if mem and "error" not in mem:
            live = mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
            row["fits_hbm"] = live <= HBM_BYTES
            row["live_bytes"] = live
        rows.append(row)
    return rows


def advice(row) -> str:
    b = row["bottleneck"]
    if b == "collective":
        return "reshard to cut cross-device traffic (fewer all-gathers; overlap with compute)"
    if b == "memory":
        if row["useful_ratio"] < 0.5:
            return "remat/recompute waste dominates HBM traffic: relax checkpoint policy"
        return "weights-bound: increase per-device work (larger microbatch) or shard params further"
    if row["useful_ratio"] < 0.6:
        return "compute-bound but much of it is non-useful (remat / causal waste): cut recompute"
    return "compute-bound and mostly useful: near roofline; tune kernel tiling"


def main(full: bool = False):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    rows = analyze(os.path.join(root, "dryrun_results.json"), os.path.join(root, "calib_results.json"))
    print(
        "roofline,arch,shape,compute_s,memory_s,memory_s_hlo_bound,collective_s,"
        "bottleneck,model_flops_ratio,roofline_frac,fits_16GB"
    )
    for row in rows:
        print(
            f"roofline,{row['arch']},{row['shape']},{row['compute_s']:.4f},{row['memory_s']:.4f},"
            f"{row.get('memory_s_hlo', 0):.4f},"
            f"{row['collective_s']:.4f},{row['bottleneck']},{row['useful_ratio']:.3f},"
            f"{row['roofline_frac']:.3f},{row.get('fits_hbm', '?')}"
        )
    return rows


if __name__ == "__main__":
    main()
