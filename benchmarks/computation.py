"""Paper Fig. 9: YCSB throughput vs execution-phase computation time.

More local computation starves the RPC handler (shared CPU) while the
one-sided plane is unaffected — the gap should close as computation grows.
exec_ticks is a traced knob: the {plane} x {exec} grid per protocol is one
compiled program."""
from __future__ import annotations

from repro.api import ExperimentSpec, grid_product, run
from repro.core.costmodel import ONE_SIDED, RPC


def main(full: bool = False):
    sweep = (1, 2, 4, 8, 16, 32) if full else (1, 8, 32)  # exec ticks (x2us)
    protos = ("nowait", "occ", "sundial") if not full else (
        "nowait", "waitdie", "occ", "mvcc", "sundial"
    )
    print("figure9,protocol,impl,exec_us,throughput_ktps")
    rows = []
    for proto in protos:
        cfgs = grid_product(hybrid=[(RPC,) * 6, (ONE_SIDED,) * 6], exec_ticks=list(sweep))
        ms = run(ExperimentSpec(protocol=proto, workload="ycsb", configs=cfgs, ticks=240)).rows
        for cfg, m in zip(cfgs, ms):
            impl = "rpc" if cfg["hybrid"][0] == RPC else "one_sided"
            rows.append(m)
            print(f"figure9,{proto},{impl},{cfg['exec_ticks']*2},{m['throughput_mtps']*1e3:.1f}")
    return rows


if __name__ == "__main__":
    main()
