"""Paper Fig. 9: YCSB throughput vs execution-phase computation time.

More local computation starves the RPC handler (shared CPU) while the
one-sided plane is unaffected — the gap should close as computation grows.
"""
from __future__ import annotations

from repro.core.costmodel import ONE_SIDED, RPC

from benchmarks.common import run_cell


def main(full: bool = False):
    sweep = (1, 2, 4, 8, 16, 32) if full else (1, 8, 32)  # exec ticks (x2us)
    protos = ("nowait", "occ", "sundial") if not full else (
        "nowait", "waitdie", "occ", "mvcc", "sundial"
    )
    print("figure9,protocol,impl,exec_us,throughput_ktps")
    rows = []
    for proto in protos:
        for impl, prim in (("rpc", RPC), ("one_sided", ONE_SIDED)):
            for et in sweep:
                m, _, _ = run_cell(proto, "ycsb", (prim,) * 6, exec_ticks=et, ticks=240)
                rows.append(m)
                print(f"figure9,{proto},{impl},{et*2},{m['throughput_mtps']*1e3:.1f}")
    return rows


if __name__ == "__main__":
    main()
