"""Benchmark entry point: one module per paper figure/table + roofline.

Default mode keeps sizes CI-friendly (single CPU core); ``--full`` runs the
paper-scale sweeps.  Output: CSV lines prefixed by figure id.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# allow `python benchmarks/run.py` from a repo checkout: put the repo root
# (for the benchmarks package) and src/ (for repro, when not pip-installed)
# on sys.path
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


# name -> module (import path under benchmarks/); single source for the
# dispatch order, --only validation, and the help text
BENCHMARKS = {
    "stage_latency": "stage_latency",
    "overall": "overall",
    "coroutines": "coroutines",
    "contention": "contention",
    "computation": "computation",
    "qp_scaling": "qp_scaling",
    "hybrid": "hybrid_search",
    "mvcc_slots": "mvcc_slots",
    "roofline": "roofline",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="all", help="comma list: " + ",".join(BENCHMARKS))
    ap.add_argument(
        "--node-shards",
        type=int,
        default=0,
        help="shard the simulated n_nodes axis over this many devices "
        "(engine.run_sharded); forces fake host devices when needed.  "
        "Honored by benchmarks with single-config cells (stage_latency); "
        "grid benchmarks keep config-axis sharding over the same devices",
    )
    ap.add_argument(
        "--devices",
        type=int,
        default=0,
        help="force this many (fake) host devices for config-axis sharding "
        "(run_grid_sharded picks them up automatically)",
    )
    args = ap.parse_args()
    want = None if args.only == "all" else set(args.only.split(","))
    if want and not want <= set(BENCHMARKS):
        ap.error(
            f"unknown benchmark(s): {sorted(want - set(BENCHMARKS))}; known: {sorted(BENCHMARKS)}"
        )

    n_dev = max(args.node_shards, args.devices)
    if n_dev > 1:
        if "jax" in sys.modules:
            ap.error("--node-shards/--devices must be set before jax is imported")
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n_dev}"
        ).strip()

    import importlib

    from benchmarks import common

    common.NODE_SHARDS = args.node_shards or None

    modules = [
        (name, importlib.import_module(f"benchmarks.{modname}"))
        for name, modname in BENCHMARKS.items()
    ]
    t0 = time.time()
    for name, mod in modules:
        if want and name not in want:
            continue
        print(f"# === {name} ({time.time()-t0:.0f}s elapsed) ===", flush=True)
        try:
            mod.main(full=args.full)
        except FileNotFoundError as e:
            print(f"# {name}: skipped ({e})")
    print(f"# all benchmarks done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
