"""Benchmark entry point: one module per paper figure/table + roofline.

Default mode keeps sizes CI-friendly (single CPU core); ``--full`` runs the
paper-scale sweeps.  Output: CSV lines prefixed by figure id.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# allow `python benchmarks/run.py` from a repo checkout: put the repo root
# (for the benchmarks package) and src/ (for repro, when not pip-installed)
# on sys.path
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


# name -> module (import path under benchmarks/); single source for the
# dispatch order, --only validation, and the help text
BENCHMARKS = {
    "stage_latency": "stage_latency",
    "overall": "overall",
    "coroutines": "coroutines",
    "contention": "contention",
    "computation": "computation",
    "qp_scaling": "qp_scaling",
    "hybrid": "hybrid_search",
    "mvcc_slots": "mvcc_slots",
    "roofline": "roofline",
}


def main() -> None:
    from benchmarks import common  # jax-free import surface (see common.py)

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="all", help="comma list: " + ",".join(BENCHMARKS))
    common.add_device_args(ap)
    args = ap.parse_args()
    want = None if args.only == "all" else set(args.only.split(","))
    if want and not want <= set(BENCHMARKS):
        ap.error(
            f"unknown benchmark(s): {sorted(want - set(BENCHMARKS))}; known: {sorted(BENCHMARKS)}"
        )

    # shared --node-shards/--devices handling (fake-host XLA_FLAGS forcing
    # must precede the first jax import, which the benchmark modules do)
    common.configure_devices(args, error=ap.error)

    import importlib

    modules = [
        (name, importlib.import_module(f"benchmarks.{modname}"))
        for name, modname in BENCHMARKS.items()
    ]
    t0 = time.time()
    for name, mod in modules:
        if want and name not in want:
            continue
        print(f"# === {name} ({time.time()-t0:.0f}s elapsed) ===", flush=True)
        try:
            mod.main(full=args.full)
        except FileNotFoundError as e:
            print(f"# {name}: skipped ({e})")
    print(f"# all benchmarks done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
