"""Benchmark entry point: one module per paper figure/table + roofline.

Default mode keeps sizes CI-friendly (single CPU core); ``--full`` runs the
paper-scale sweeps.  Output: CSV lines prefixed by figure id.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument(
        "--only",
        default="all",
        help="comma list: stage_latency,overall,coroutines,contention,computation,qp_scaling,hybrid,roofline",
    )
    args = ap.parse_args()
    want = None if args.only == "all" else set(args.only.split(","))

    from benchmarks import (
        contention,
        computation,
        coroutines,
        hybrid_search,
        mvcc_slots,
        overall,
        qp_scaling,
        roofline,
        stage_latency,
    )

    modules = [
        ("stage_latency", stage_latency),
        ("overall", overall),
        ("coroutines", coroutines),
        ("contention", contention),
        ("computation", computation),
        ("qp_scaling", qp_scaling),
        ("hybrid", hybrid_search),
        ("mvcc_slots", mvcc_slots),
        ("roofline", roofline),
    ]
    t0 = time.time()
    for name, mod in modules:
        if want and name not in want:
            continue
        print(f"# === {name} ({time.time()-t0:.0f}s elapsed) ===", flush=True)
        try:
            mod.main(full=args.full)
        except FileNotFoundError as e:
            print(f"# {name}: skipped ({e})")
    print(f"# all benchmarks done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
