"""Paper Fig. 4: per-stage latency breakdown, per protocol x primitive
(1 co-routine per thread — low-load, pure latency).  The rpc/one-sided
pair for each protocol runs as one 2-config batched grid."""
from __future__ import annotations

from repro.core.costmodel import ONE_SIDED, RPC, STAGE_NAMES

from benchmarks.common import PROTO_LIST, run_grid, stage_breakdown


def main(full: bool = False):
    workloads = ("smallbank", "ycsb", "tpcc") if full else ("smallbank",)
    print("figure4,workload,protocol,impl," + ",".join(STAGE_NAMES))
    out = {}
    for wlname in workloads:
        for proto in PROTO_LIST:
            ms = run_grid(
                proto,
                wlname,
                [{"hybrid": (RPC,) * 6}, {"hybrid": (ONE_SIDED,) * 6}],
                coroutines=10,
                ticks=300,
                warmup=60,
            )
            for impl, m in zip(("rpc", "one_sided"), ms):
                b = stage_breakdown(m)
                out[(wlname, proto, impl)] = b
                print(
                    f"figure4,{wlname},{proto},{impl},"
                    + ",".join(f"{b[s]:.3f}" for s in STAGE_NAMES)
                )
    return out


if __name__ == "__main__":
    main()
