"""Paper Fig. 4: per-stage latency breakdown, per protocol x primitive
(1 co-routine per thread — low-load, pure latency).  The rpc/one-sided
pair for each protocol runs as one 2-config batched grid; under
``benchmarks/run.py --node-shards N`` each cell instead runs with the
simulated cluster SPMD on an N-device node mesh (the api 'node' layout;
same counters — the sharded engine is bitwise-equivalent — so the figure
is unchanged)."""
from __future__ import annotations

from repro.api import ExperimentSpec, run
from repro.core.costmodel import ONE_SIDED, RPC, STAGE_NAMES

from benchmarks import common
from benchmarks.common import PROTO_LIST, stage_breakdown


def main(full: bool = False):
    workloads = ("smallbank", "ycsb", "tpcc") if full else ("smallbank",)
    print("figure4,workload,protocol,impl," + ",".join(STAGE_NAMES))
    out = {}
    for wlname in workloads:
        for proto in PROTO_LIST:
            kw = dict(coroutines=10, ticks=300, warmup=60)
            codes = [{"hybrid": (RPC,) * 6}, {"hybrid": (ONE_SIDED,) * 6}]
            if common.NODE_SHARDS:
                ms = [
                    run(
                        ExperimentSpec(
                            protocol=proto,
                            workload=wlname,
                            configs=(c,),
                            node_shards=common.NODE_SHARDS,
                            layout="node",
                            **kw,
                        )
                    ).row
                    for c in codes
                ]
            else:
                ms = run(
                    ExperimentSpec(protocol=proto, workload=wlname, configs=codes, **kw)
                ).rows
            for impl, m in zip(("rpc", "one_sided"), ms):
                b = stage_breakdown(m)
                out[(wlname, proto, impl)] = b
                print(
                    f"figure4,{wlname},{proto},{impl},"
                    + ",".join(f"{b[s]:.3f}" for s in STAGE_NAMES)
                )
    return out


if __name__ == "__main__":
    main()
