"""Paper Fig. 6/7: throughput+latency vs #co-routines (incl. CALVIN).

The co-routine count is a STATIC shape axis, historically one compile (and
one Python-loop iteration) per point.  Ported to the bucketed sweep API:
each protocol's whole {plane} x {co-routine count} grid goes through
``repro.api``, whose planner groups the counts into power-of-two shape
buckets and runs one compiled program per bucket with padded slots masked
inert (DESIGN.md §6, §8).
"""
from __future__ import annotations

from repro.api import ExperimentSpec, run
from repro.core.costmodel import ONE_SIDED, RPC


def main(full: bool = False):
    sweep = (10, 30, 50, 70, 90, 110) if full else (10, 40, 70)
    protos = ("nowait", "occ", "sundial", "calvin") if not full else (
        "nowait", "waitdie", "occ", "mvcc", "sundial", "calvin"
    )
    print("figure6,protocol,impl,coroutines_per_node,throughput_ktps,avg_latency_us")
    rows = []
    for proto in protos:
        cells = [
            (impl, c, {"hybrid": (prim,) * 6, "coroutines": c})
            for impl, prim in (("rpc", RPC), ("one_sided", ONE_SIDED))
            for c in sweep
        ]
        ms = run(
            ExperimentSpec(
                protocol=proto,
                workload="smallbank",
                configs=[cfg for _, _, cfg in cells],
                ticks=240,
            )
        ).rows
        for (impl, c, _), m in zip(cells, ms):
            rows.append(m)
            print(
                f"figure6,{proto},{impl},{c},{m['throughput_mtps']*1e3:.1f},"
                f"{m['avg_latency_us']:.2f}"
            )
    return rows


if __name__ == "__main__":
    main()
