"""Paper Fig. 6/7: throughput+latency vs #co-routines (incl. CALVIN)."""
from __future__ import annotations

from repro.core.costmodel import ONE_SIDED, RPC

from benchmarks.common import run_cell


def main(full: bool = False):
    sweep = (10, 30, 50, 70, 90, 110) if full else (10, 40, 70)
    protos = ("nowait", "occ", "sundial", "calvin") if not full else (
        "nowait", "waitdie", "occ", "mvcc", "sundial", "calvin"
    )
    print("figure6,protocol,impl,coroutines_per_node,throughput_ktps,avg_latency_us")
    rows = []
    for proto in protos:
        for impl, prim in (("rpc", RPC), ("one_sided", ONE_SIDED)):
            for c in sweep:
                m, _, _ = run_cell(proto, "smallbank", (prim,) * 6, coroutines=c, ticks=240)
                rows.append(m)
                print(
                    f"figure6,{proto},{impl},{c},{m['throughput_mtps']*1e3:.1f},"
                    f"{m['avg_latency_us']:.2f}"
                )
    return rows


if __name__ == "__main__":
    main()
