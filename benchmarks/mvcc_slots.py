"""Paper §4.4 ablation: MVCC static version-slot count.

The paper chose 4 slots because "at most 4.2% of read aborts are due to
slot overflow".  We sweep slots and attribute the abort-rate delta vs a
deep (16-slot) store to overflow.
"""
from __future__ import annotations

import jax

from repro.core.costmodel import ONE_SIDED, CostModel
from repro.core.engine import EngineConfig, run
from repro.core.registry import get_protocol
from repro.workloads import make_workload


def _run(slots: int, ticks: int):
    # custom workload surgery (op-count truncation) isn't expressible as an
    # ExperimentSpec, so this benchmark drives the engine kernel directly
    # with the registry-resolved tick — the sanctioned extension path
    ec = EngineConfig(
        protocol="mvcc", n_nodes=4, coroutines=40, records_per_node=512,
        rw=2, max_ops=4, hybrid=(ONE_SIDED,) * 6, mvcc_slots=slots,
    )
    wl = make_workload("ycsb", ec.n_records, hot_prob=0.6)
    wl = wl._replace(max_ops=4, gen=_trunc(wl.gen, 4))
    ec = EngineConfig(**{**ec.__dict__, "rw": wl.rw, "max_ops": wl.max_ops})
    tick = get_protocol("mvcc").tick
    _, _, m = jax.jit(lambda: run(tick, ec, CostModel(), wl, ticks, warmup=40))()
    return float(m["abort_rate"]), int(m["commits"])


def _trunc(gen, k):
    def g(key, node, slot):
        keys, is_w, valid = gen(key, node, slot)
        return keys[:k], is_w[:k], valid[:k]

    return g


def main(full: bool = False):
    ticks = 300 if full else 200
    print("mvcc_slots,slots,abort_rate,overflow_attributable")
    base_ab, _ = _run(16, ticks)  # deep store: ~no overflow aborts
    for slots in (2, 3, 4, 8):
        ab, commits = _run(slots, ticks)
        overflow = max(ab - base_ab, 0.0) / max(ab, 1e-9)
        print(f"mvcc_slots,{slots},{ab:.4f},{overflow:.3f}")
    print(f"mvcc_slots,16,{base_ab:.4f},0.000")


if __name__ == "__main__":
    main()
