"""Paper Fig. 10: emulated large clusters — QP-state pressure degrades the
RNIC, closing the one-sided advantage as the cluster grows.  qp_pressure is
a traced knob, so the whole {plane} x {cluster size} grid per protocol is
one compiled program, and ``devices="auto"`` additionally splits the grid
axis across every visible device (a no-op on one device)."""
from __future__ import annotations

from repro.api import ExperimentSpec, run
from repro.core.costmodel import ONE_SIDED, RPC


def _pressure(n_nodes_emulated: int) -> float:
    # QP cache thrashing grows with per-node connection count
    return max(0.0, (n_nodes_emulated - 16) / 64.0)


def main(full: bool = False):
    sweep = (4, 40, 80, 160) if full else (4, 80, 160)
    print("figure10,protocol,impl,emulated_nodes,throughput_ktps")
    rows = []
    for proto in ("nowait", "occ", "sundial"):
        cells = [
            (
                impl,
                n,
                {
                    "hybrid": (prim,) * 6,
                    "hot_prob": 0.9,
                    "qp_pressure": _pressure(n) if prim == ONE_SIDED else 0.0,
                },
            )
            for impl, prim in (("rpc", RPC), ("one_sided", ONE_SIDED))
            for n in sweep
        ]
        ms = run(
            ExperimentSpec(
                protocol=proto,
                workload="ycsb",
                configs=[c for _, _, c in cells],
                ticks=240,
                devices="auto",
            )
        ).rows
        for (impl, n, _), m in zip(cells, ms):
            rows.append(m)
            print(f"figure10,{proto},{impl},{n},{m['throughput_mtps']*1e3:.1f}")
    return rows


if __name__ == "__main__":
    main()
