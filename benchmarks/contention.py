"""Paper Fig. 8: YCSB throughput vs contention (hot-access probability).

The whole {plane} x {hot_prob} grid for each protocol runs as one vmapped
program — hot_prob is a traced knob, so the sweep costs one compilation
per protocol regardless of its resolution.
"""
from __future__ import annotations

from repro.api import ExperimentSpec, grid_product, run
from repro.core.costmodel import ONE_SIDED, RPC

from benchmarks.common import PROTO_LIST


def main(full: bool = False):
    sweep = (0.0, 0.1, 0.3, 0.5, 0.7, 0.9) if full else (0.0, 0.5, 0.9)
    print("figure8,protocol,impl,hot_prob,throughput_ktps,abort_rate")
    rows = []
    impls = (("rpc", RPC), ("one_sided", ONE_SIDED))
    for proto in PROTO_LIST:
        cfgs = grid_product(hybrid=[(p,) * 6 for _, p in impls], hot_prob=list(sweep))
        ms = run(ExperimentSpec(protocol=proto, workload="ycsb", configs=cfgs, ticks=240)).rows
        for cfg, m in zip(cfgs, ms):
            impl = "rpc" if cfg["hybrid"][0] == RPC else "one_sided"
            rows.append(m)
            print(
                f"figure8,{proto},{impl},{cfg['hot_prob']},{m['throughput_mtps']*1e3:.1f},"
                f"{m['abort_rate']:.4f}"
            )
    return rows


if __name__ == "__main__":
    main()
