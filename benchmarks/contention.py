"""Paper Fig. 8: YCSB throughput vs contention (hot-access probability)."""
from __future__ import annotations

from repro.core.costmodel import ONE_SIDED, RPC

from benchmarks.common import PROTO_LIST, run_cell


def main(full: bool = False):
    sweep = (0.0, 0.1, 0.3, 0.5, 0.7, 0.9) if full else (0.0, 0.5, 0.9)
    print("figure8,protocol,impl,hot_prob,throughput_ktps,abort_rate")
    rows = []
    for proto in PROTO_LIST:
        for impl, prim in (("rpc", RPC), ("one_sided", ONE_SIDED)):
            for hp in sweep:
                m, _, _ = run_cell(proto, "ycsb", (prim,) * 6, hot_prob=hp, ticks=240)
                rows.append(m)
                print(
                    f"figure8,{proto},{impl},{hp},{m['throughput_mtps']*1e3:.1f},"
                    f"{m['abort_rate']:.4f}"
                )
    return rows


if __name__ == "__main__":
    main()
