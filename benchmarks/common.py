"""Shared benchmark runner: one (protocol, workload, hybrid, knobs) cell."""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.costmodel import N_HYBRID_STAGES, ONE_SIDED, RPC, STAGE_NAMES, CostModel
from repro.core.engine import EngineConfig, run
from repro.core.protocols import PROTOCOLS
from repro.core.protocols import calvin as calvin_mod
from repro.workloads import make_workload

PROTO_LIST = ("nowait", "waitdie", "occ", "mvcc", "sundial")  # slot-engine protocols


def run_cell(
    protocol: str,
    workload: str,
    hybrid,
    *,
    n_nodes: int = 4,
    coroutines: int = 60,
    records_per_node: int = 65536,  # paper-scale: 0.1% hot area >> the 16-record floor
    ticks: int = 400,
    warmup: int = 80,
    exec_ticks: Optional[int] = None,
    hot_prob: Optional[float] = None,
    qp_pressure: float = 0.0,
    history_cap: int = 0,
    seed: int = 0,
    tcp: bool = False,
) -> Dict:
    if isinstance(hybrid, int):
        hybrid = tuple((hybrid >> i) & 1 for i in range(N_HYBRID_STAGES))
    hybrid = tuple(int(b) for b in hybrid)
    cm = CostModel.tcp() if tcp else CostModel(qp_pressure=qp_pressure)
    kw = {}
    if hot_prob is not None:
        kw["hot_prob"] = hot_prob
    if exec_ticks is not None:
        kw["exec_ticks"] = exec_ticks
    n_records = n_nodes * records_per_node
    wl = make_workload(workload, n_records, **kw)
    ec = EngineConfig(
        protocol=protocol,
        n_nodes=n_nodes,
        coroutines=coroutines,
        records_per_node=records_per_node,
        rw=wl.rw,
        max_ops=wl.max_ops,
        hybrid=hybrid,
        history_cap=history_cap,
        seed=seed,
    )
    t0 = time.time()
    if protocol == "calvin":
        n_epochs = max(ticks // 8, 8)
        store, m = jax.jit(lambda: calvin_mod.run_epochs(ec, cm, wl, n_epochs))()
        st = None
    else:
        proto = PROTOCOLS[protocol]
        st, store, m = jax.jit(lambda: run(proto.tick, ec, cm, wl, ticks, warmup=warmup))()
    m = {k: (v.tolist() if hasattr(v, "tolist") else v) for k, v in m.items()}
    m["wall_s"] = round(time.time() - t0, 2)
    m["protocol"], m["workload"], m["hybrid"] = protocol, workload, "".join(map(str, hybrid))
    return m, st, store


def stage_breakdown(m: Dict) -> Dict[str, float]:
    return dict(zip(STAGE_NAMES, m["stage_us_per_commit"]))


def cherry_pick_hybrid(protocol: str, workload: str, **kw):
    """Paper §5.1: pick the lower-latency primitive per stage from the pure
    RPC and pure one-sided stage breakdowns."""
    m_rpc, _, _ = run_cell(protocol, workload, (RPC,) * N_HYBRID_STAGES, **kw)
    m_os, _, _ = run_cell(protocol, workload, (ONE_SIDED,) * N_HYBRID_STAGES, **kw)
    code = tuple(
        RPC if m_rpc["stage_us_per_commit"][s] <= m_os["stage_us_per_commit"][s] else ONE_SIDED
        for s in range(N_HYBRID_STAGES)
    )
    return code, m_rpc, m_os
