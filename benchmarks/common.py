"""Shared benchmark helpers around the ``repro.api`` front door.

Two layers live here:

  * **Device/topology CLI flags** (``add_device_args`` / ``configure_devices``):
    the one place ``--devices`` / ``--node-shards`` / fake-host XLA_FLAGS
    forcing is parsed, shared by ``benchmarks/run.py``,
    ``scripts/dev_smoke.py`` and ``scripts/perf_gate.py``.  Forcing fake
    host devices must happen BEFORE jax is imported, so this module keeps
    its import surface jax-free — every heavy import below is local to the
    function that needs it.
  * **Cell helpers**: ``run_cell`` is the sequential reference path (its own
    jit per cell, used by the batched-vs-sequential equivalence tests);
    ``cherry_pick_hybrid`` builds the paper §5.1 per-stage hybrid through
    ``repro.api``.

Benchmark modules take their grids straight from ``repro.api``
(``ExperimentSpec`` → ``plan`` → ``execute``); the legacy sweep entry
points are deprecated shims, banned here by scripts/check_api_boundary.py.
"""
from __future__ import annotations

import os
import sys
import time
from typing import Dict, Optional, Tuple

PROTO_LIST = ("nowait", "waitdie", "occ", "mvcc", "sundial")  # slot-engine protocols

# set by configure_devices (--node-shards): benchmarks that support it run
# their single-config cells with the simulated n_nodes axis SPMD on the
# first N devices (the api 'node' layout); None = dense engine
NODE_SHARDS: Optional[int] = None


def add_device_args(ap) -> None:
    """Install the shared ``--node-shards`` / ``--devices`` flags on a parser."""
    ap.add_argument(
        "--node-shards",
        type=int,
        default=0,
        help="shard the simulated n_nodes axis over this many devices "
        "(the repro.api 'node' layout); forces fake host devices when "
        "needed.  Honored by surfaces with single-config cells "
        "(stage_latency); grid surfaces keep config-axis sharding over "
        "the same devices",
    )
    ap.add_argument(
        "--devices",
        type=int,
        default=0,
        help="force this many (fake) host devices for config-axis sharding "
        "(repro.api picks them up via devices='auto')",
    )


def configure_devices(args, *, error=None) -> int:
    """Apply the shared device flags; MUST run before jax is imported.

    Appends ``--xla_force_host_platform_device_count`` to ``XLA_FLAGS`` when
    more than one device is requested and records ``--node-shards`` in
    :data:`NODE_SHARDS` for single-config surfaces.  ``error`` is the
    parser's ``.error`` (or any callable raising); defaults to SystemExit.
    Returns the forced device count (0/1 = no forcing).
    """
    global NODE_SHARDS

    def fail(msg: str):
        if error is not None:
            error(msg)
        raise SystemExit(f"error: {msg}")

    n_dev = max(args.node_shards, args.devices)
    if n_dev > 1:
        if "jax" in sys.modules:
            fail("--node-shards/--devices must be set before jax is imported")
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n_dev}"
        ).strip()
    NODE_SHARDS = args.node_shards or None
    return n_dev


def split_knobs(kw: Dict) -> Tuple[Dict, Dict]:
    """Split run_cell-style kwargs into (per-run knobs, static grid kwargs)."""
    from repro.api import KNOB_KEYS

    knobs = {k: kw[k] for k in KNOB_KEYS if k in kw and kw[k] is not None}
    static = {k: v for k, v in kw.items() if k not in KNOB_KEYS}
    return knobs, static


def run_cell(
    protocol: str,
    workload: str,
    hybrid,
    *,
    n_nodes: int = 4,
    coroutines: int = 60,
    records_per_node: int = 65536,  # paper-scale: 0.1% hot area >> the 16-record floor
    ticks: int = 400,
    warmup: int = 80,
    exec_ticks: Optional[int] = None,
    hot_prob: Optional[float] = None,
    qp_pressure: float = 0.0,
    history_cap: int = 0,
    seed: int = 0,
    tcp: bool = False,
    merge_stages: bool = False,
):
    """One (protocol, workload, hybrid, knobs) cell under its own jit — the
    sequential reference path the batched sweep is pinned against.

    Returns ``(metrics, state, store)`` for tick-driven protocols;
    epoch-driven registry entries (``entry.tick is None``, e.g. CALVIN) own
    their run loop through hooks and return ``(metrics, None, None)``.
    """
    import jax

    from repro.api import normalize_hybrid
    from repro.core.costmodel import CostModel
    from repro.core.engine import EngineConfig, run
    from repro.core.registry import get_protocol
    from repro.workloads import make_workload

    entry = get_protocol(protocol)
    hybrid = normalize_hybrid(hybrid)
    cm = CostModel.tcp() if tcp else CostModel(qp_pressure=qp_pressure)
    kw = {}
    if hot_prob is not None:
        kw["hot_prob"] = hot_prob
    if exec_ticks is not None:
        kw["exec_ticks"] = exec_ticks
    n_records = n_nodes * records_per_node
    wl = make_workload(workload, n_records, **kw)
    ec = EngineConfig(
        protocol=protocol,
        n_nodes=n_nodes,
        coroutines=coroutines,
        records_per_node=records_per_node,
        rw=wl.rw,
        max_ops=wl.max_ops,
        hybrid=hybrid,
        merge_stages=merge_stages,
        exec_ticks=wl.exec_ticks,  # keep handler starvation in sync with the workload
        history_cap=history_cap,
        seed=seed,
    )
    t0 = time.time()
    if entry.tick is None:  # epoch-driven protocols own their run loop
        m = jax.jit(
            lambda: entry.hooks.grid_run(
                entry, ec, cm, wl, ticks=ticks, warmup=warmup, ticks_active=None
            )
        )()
        st = store = None
    else:
        st, store, m = jax.jit(lambda: run(entry.tick, ec, cm, wl, ticks, warmup=warmup))()
    m = {k: (v.tolist() if hasattr(v, "tolist") else v) for k, v in m.items()}
    m["wall_s"] = round(time.time() - t0, 2)
    m["protocol"], m["workload"], m["hybrid"] = protocol, workload, "".join(map(str, hybrid))
    return m, st, store


def stage_breakdown(m: Dict) -> Dict[str, float]:
    from repro.core.costmodel import STAGE_NAMES

    return dict(zip(STAGE_NAMES, m["stage_us_per_commit"]))


def cherry_pick_hybrid(protocol: str, workload: str, **kw):
    """Paper §5.1: pick the lower-latency primitive per stage from the pure
    RPC and pure one-sided stage breakdowns (both run in one planned grid)."""
    from repro import api
    from repro.core.costmodel import N_HYBRID_STAGES, ONE_SIDED, RPC

    knobs, static = split_knobs(kw)
    m_rpc, m_os = api.run(
        api.ExperimentSpec(
            protocol=protocol,
            workload=workload,
            configs=(
                dict(knobs, hybrid=(RPC,) * N_HYBRID_STAGES),
                dict(knobs, hybrid=(ONE_SIDED,) * N_HYBRID_STAGES),
            ),
            **static,
        )
    ).rows
    code = tuple(
        RPC if m_rpc["stage_us_per_commit"][s] <= m_os["stage_us_per_commit"][s] else ONE_SIDED
        for s in range(N_HYBRID_STAGES)
    )
    return code, m_rpc, m_os
