"""Shared benchmark runners.

``run_cell`` runs one (protocol, workload, hybrid, knobs) cell under its own
jit — the sequential reference path.  ``run_grid`` (re-exported from
``repro.core.sweep``) runs a whole grid of knob settings as one vmapped
program: the 2^6 hybrid enumeration compiles once instead of 64 times.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import jax

from repro.core.costmodel import N_HYBRID_STAGES, ONE_SIDED, RPC, STAGE_NAMES, CostModel
from repro.core.engine import EngineConfig, run
from repro.core.protocols import PROTOCOLS
from repro.core.protocols import calvin as calvin_mod
from repro.core.sweep import (  # noqa: F401
    all_hybrid_codes,
    grid_product,
    normalize_hybrid,
    plan_buckets,
    run_cell_sharded,
    run_grid,
    run_grid_sharded,
)
from repro.core.sweep import KNOB_KEYS as _KNOB_KEYS
from repro.workloads import make_workload

PROTO_LIST = ("nowait", "waitdie", "occ", "mvcc", "sundial")  # slot-engine protocols

# set by benchmarks/run.py --node-shards: benchmarks that support it run
# their single-config cells with the simulated n_nodes axis SPMD on the
# first N devices (repro.core.engine.run_sharded); None = dense engine
NODE_SHARDS: Optional[int] = None


def split_knobs(kw: Dict) -> Tuple[Dict, Dict]:
    """Split run_cell-style kwargs into (per-run knobs, static grid kwargs)."""
    knobs = {k: kw[k] for k in _KNOB_KEYS if k in kw and kw[k] is not None}
    static = {k: v for k, v in kw.items() if k not in _KNOB_KEYS}
    return knobs, static


def run_cell(
    protocol: str,
    workload: str,
    hybrid,
    *,
    n_nodes: int = 4,
    coroutines: int = 60,
    records_per_node: int = 65536,  # paper-scale: 0.1% hot area >> the 16-record floor
    ticks: int = 400,
    warmup: int = 80,
    exec_ticks: Optional[int] = None,
    hot_prob: Optional[float] = None,
    qp_pressure: float = 0.0,
    history_cap: int = 0,
    seed: int = 0,
    tcp: bool = False,
    merge_stages: bool = False,
) -> Dict:
    hybrid = normalize_hybrid(hybrid)
    cm = CostModel.tcp() if tcp else CostModel(qp_pressure=qp_pressure)
    kw = {}
    if hot_prob is not None:
        kw["hot_prob"] = hot_prob
    if exec_ticks is not None:
        kw["exec_ticks"] = exec_ticks
    n_records = n_nodes * records_per_node
    wl = make_workload(workload, n_records, **kw)
    ec = EngineConfig(
        protocol=protocol,
        n_nodes=n_nodes,
        coroutines=coroutines,
        records_per_node=records_per_node,
        rw=wl.rw,
        max_ops=wl.max_ops,
        hybrid=hybrid,
        merge_stages=merge_stages,
        exec_ticks=wl.exec_ticks,  # keep handler starvation in sync with the workload
        history_cap=history_cap,
        seed=seed,
    )
    t0 = time.time()
    if protocol == "calvin":
        n_epochs = max(ticks // 8, 8)
        store, m = jax.jit(lambda: calvin_mod.run_epochs(ec, cm, wl, n_epochs))()
        st = None
    else:
        proto = PROTOCOLS[protocol]
        st, store, m = jax.jit(lambda: run(proto.tick, ec, cm, wl, ticks, warmup=warmup))()
    m = {k: (v.tolist() if hasattr(v, "tolist") else v) for k, v in m.items()}
    m["wall_s"] = round(time.time() - t0, 2)
    m["protocol"], m["workload"], m["hybrid"] = protocol, workload, "".join(map(str, hybrid))
    return m, st, store


def stage_breakdown(m: Dict) -> Dict[str, float]:
    return dict(zip(STAGE_NAMES, m["stage_us_per_commit"]))


def cherry_pick_hybrid(protocol: str, workload: str, **kw):
    """Paper §5.1: pick the lower-latency primitive per stage from the pure
    RPC and pure one-sided stage breakdowns (both run in one batched grid)."""
    knobs, static = split_knobs(kw)
    m_rpc, m_os = run_grid(
        protocol,
        workload,
        [
            dict(knobs, hybrid=(RPC,) * N_HYBRID_STAGES),
            dict(knobs, hybrid=(ONE_SIDED,) * N_HYBRID_STAGES),
        ],
        **static,
    )
    code = tuple(
        RPC if m_rpc["stage_us_per_commit"][s] <= m_os["stage_us_per_commit"][s] else ONE_SIDED
        for s in range(N_HYBRID_STAGES)
    )
    return code, m_rpc, m_os
