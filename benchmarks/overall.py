"""Paper Fig. 5: overall throughput / latency / abort rate / round trips for
all six protocols x {rpc, one-sided, hybrid} x {smallbank, ycsb, tpcc}.

Each (protocol, workload) compiles three programs: the rpc / one-sided
pair as one 2-config grid, the cherry-picked hybrid as a 1-config grid
(jit caches on the knob batch shape, so grid sizes 2 and 1 are distinct
programs), and the TCP plane (different static CostModel).
"""
from __future__ import annotations

from repro.api import ExperimentSpec, run
from repro.core.costmodel import ONE_SIDED, RPC

from benchmarks.common import PROTO_LIST, cherry_pick_hybrid


def main(full: bool = False):
    rows = []
    workloads = ("smallbank", "ycsb", "tpcc")
    protos = PROTO_LIST + ("calvin",)
    kw = dict(ticks=400 if full else 240, coroutines=60 if full else 40)
    for wlname in workloads:
        for proto in protos:
            if proto == "calvin":
                m_rpc, m_os = run(
                    ExperimentSpec(
                        protocol=proto,
                        workload=wlname,
                        configs=({"hybrid": (RPC,) * 6}, {"hybrid": (ONE_SIDED,) * 6}),
                        **kw,
                    )
                ).rows
                rows.append(("rpc", m_rpc))
                rows.append(("one_sided", m_os))
            else:
                code, m_rpc, m_os = cherry_pick_hybrid(proto, wlname, **kw)
                rows.append(("rpc", m_rpc))
                rows.append(("one_sided", m_os))
                (m_h,) = run(
                    ExperimentSpec(
                        protocol=proto, workload=wlname, configs=({"hybrid": code},), **kw
                    )
                ).rows
                rows.append(("hybrid", m_h))
            # reference TCP plane (paper §6.1 includes TCP baselines)
            (m_tcp,) = run(
                ExperimentSpec(
                    protocol=proto,
                    workload=wlname,
                    configs=({"hybrid": (RPC,) * 6},),
                    tcp=True,
                    **kw,
                )
            ).rows
            rows.append(("tcp", m_tcp))
    print("figure5,workload,protocol,impl,hybrid_code,throughput_ktps,avg_latency_us,abort_rate,round_trips")
    for impl, m in rows:
        print(
            f"figure5,{m['workload']},{m['protocol']},{impl},{m['hybrid']},"
            f"{m['throughput_mtps']*1e3:.1f},{m['avg_latency_us']:.2f},"
            f"{m['abort_rate']:.4f},{m['avg_round_trips']:.2f}"
        )
    return rows


if __name__ == "__main__":
    main()
